//! Multi-level map-reduce (§II): nested LLMapReduce over hierarchies.
//!
//! "Many filesystems operate best when the number of files per directory
//! is less than 10,000.  LLMapReduce users can build a nested call to
//! LLMapReduce for processing whole hierarchies of data."
//!
//! The outer level maps over the subdirectories of the input root — one
//! *inner* LLMapReduce invocation per leaf directory — and an optional
//! outer reducer merges the per-directory reduce outputs bottom-up.
//! This is the paper's title feature: map-reduce jobs whose mappers are
//! themselves map-reduce jobs.
//!
//! # Concurrent fan-out
//!
//! Inner pipelines are independent, so they are *all submitted up
//! front* through one [`Session`] and only then waited: every
//! subdirectory's map/reduce jobs share the engine's slot cap
//! concurrently instead of running branch-by-branch.  Removing that
//! serialization is the same barrier argument as `--overlap` (DESIGN.md
//! §4), one level up — `cargo bench --bench multilevel` measures the
//! win over the serial path.
//!
//! Each leaf invocation gets a collision-free derived pid
//! (`base_pid * 1000 + seq`, `seq` enumerating leaves across the whole
//! tree), so any fan-out width or depth keeps distinct `.MAPRED.<pid>`
//! and `.partials.<pid>` scratch.  The per-level merge stages through
//! `.multilevel.<base_pid>`, likewise pid-suffixed so concurrent nested
//! runs can share an output root.

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::apps::ReduceApp;
use crate::error::{Error, IoContext, Result};
use crate::mapreduce::pipeline::{run, Apps, MapReduceReport};
use crate::mapreduce::session::{Invocation, Session};
use crate::options::Options;
use crate::scheduler::Engine;

/// Report for a nested invocation.
#[derive(Debug)]
pub struct MultiLevelReport {
    /// (subdirectory path, inner report) per inner invocation.
    pub inner: Vec<(String, MapReduceReport)>,
    /// Path of the final merged output, when an outer reducer ran.
    pub final_out: Option<PathBuf>,
    /// End-to-end elapsed time of the whole nested run, mirroring
    /// [`MapReduceReport::total_elapsed`]: wall-clock engines measure
    /// the true submit→wait span (inner invocations overlap, so summing
    /// their elapsed times would double-count); virtual-time engines
    /// report the summed inner elapsed (the simulator serializes, so
    /// the sum *is* its end-to-end time).
    pub total_elapsed: Duration,
}

impl MultiLevelReport {
    pub fn total_items(&self) -> usize {
        self.inner.iter().map(|(_, r)| r.map.total_items()).sum()
    }

    /// End-to-end elapsed (virtual or wall) time of the nested run.
    pub fn elapsed(&self) -> Duration {
        self.total_elapsed
    }

    /// Sum of the inner invocations' elapsed times — slot-time consumed
    /// rather than wall time.  On a wall-clock engine this exceeds
    /// [`MultiLevelReport::elapsed`] exactly when inner pipelines
    /// overlapped (the concurrency win); on a virtual-time engine the
    /// two agree.
    pub fn summed_elapsed(&self) -> Duration {
        self.inner.iter().map(|(_, r)| r.elapsed()).sum()
    }
}

/// Run a two-level map-reduce: one inner LLMapReduce per immediate
/// subdirectory of `opts.input`, then `outer_reducer` over the collected
/// inner reduce outputs.
///
/// Each inner invocation inherits all options but gets
/// `input = <subdir>`, `output = <output>/<subdir name>` and a derived
/// pid (see the module docs), and **all inner invocations run
/// concurrently** on the shared engine.
pub fn run_nested(
    opts: &Options,
    apps: &Apps,
    outer_reducer: Option<Arc<dyn ReduceApp>>,
    engine: &dyn Engine,
) -> Result<MultiLevelReport> {
    run_nested_depth(opts, apps, outer_reducer, engine, 1)
}

/// Run an N-level nested map-reduce: recurse `depth` levels of
/// subdirectories; the innermost level runs the ordinary pipeline over
/// its directory, and every enclosing level merges its children with
/// `outer_reducer` (when given).
///
/// `depth == 0` is a plain [`run`]; `depth == 1` equals [`run_nested`].
/// This is the paper's "whole hierarchies of data" taken literally.
/// All leaf pipelines across all branches are submitted before any is
/// waited, so the whole hierarchy shares the engine concurrently; the
/// merges then run bottom-up over the finished outputs.
pub fn run_nested_depth(
    opts: &Options,
    apps: &Apps,
    outer_reducer: Option<Arc<dyn ReduceApp>>,
    engine: &dyn Engine,
    depth: usize,
) -> Result<MultiLevelReport> {
    let t0 = Instant::now();
    if depth == 0 {
        let report = run(opts, apps, engine)?;
        let final_out = report.redout_path.clone();
        let total_elapsed = if engine.virtual_time() {
            report.elapsed()
        } else {
            t0.elapsed()
        };
        return Ok(MultiLevelReport {
            inner: vec![(String::new(), report)],
            final_out,
            total_elapsed,
        });
    }

    // Phase 1: walk the hierarchy and submit every leaf pipeline —
    // nothing is waited yet, so all branches share the slot cap.  An
    // unpinned base pid goes through the process-unique derivation:
    // two concurrent unpinned nested runs must not pin their leaves
    // (base * 1000 + seq) or their `.multilevel.<base>` staging to the
    // same raw process id.
    let session = Session::new(engine);
    let base_pid = crate::mapreduce::session::auto_pid(opts.pid);
    let mut seq: u32 = 0;
    let mut pending = Vec::new();
    let tree = submit_tree(
        &session,
        opts,
        apps,
        &opts.input,
        &opts.output,
        "",
        depth,
        base_pid,
        &mut seq,
        &mut pending,
    )?;

    // Phase 2: wait out every leaf.  On failure the remaining handles
    // drop, which blocks until their jobs settle and then cleans their
    // scratch — nothing leaks (see the session module docs).
    let mut inner = Vec::with_capacity(pending.len());
    for (name, inv) in pending {
        inner.push((name, inv.wait()?));
    }

    // Phase 3: bottom-up merges over the finished outputs.
    let final_out = match &outer_reducer {
        Some(outer) => {
            merge_tree(&tree, &inner, outer.as_ref(), &opts.redout, base_pid)?
        }
        None => None,
    };

    let total_elapsed = if engine.virtual_time() {
        inner.iter().map(|(_, r)| r.elapsed()).sum()
    } else {
        t0.elapsed()
    };
    Ok(MultiLevelReport {
        inner,
        final_out,
        total_elapsed,
    })
}

/// One node of the walked hierarchy: a leaf holds the index of its
/// submitted invocation; an internal node holds its children in sorted
/// subdirectory order.
struct TreeNode {
    /// Last path segment ("s1" for prefix "site-a/s1"); the merge names
    /// this node's part file after it.
    name: String,
    /// Output directory of this node.
    output: PathBuf,
    children: Vec<TreeNode>,
    /// Index into the submitted-invocations vector, for leaves.
    leaf: Option<usize>,
}

/// Recursively submit the leaf pipelines of `input` (at `depth` more
/// levels of nesting) through `session`, collecting handles into
/// `pending` and returning the merge tree.  `seq` enumerates leaves
/// across the whole walk, keeping every derived pid distinct no matter
/// the fan-out or depth.
#[allow(clippy::too_many_arguments)]
fn submit_tree<'e>(
    session: &Session<'e>,
    opts: &Options,
    apps: &Apps,
    input: &Path,
    output: &Path,
    prefix: &str,
    depth: usize,
    base_pid: u32,
    seq: &mut u32,
    pending: &mut Vec<(String, Invocation<'e>)>,
) -> Result<TreeNode> {
    let name = input
        .file_name()
        .and_then(|n| n.to_str())
        .unwrap_or("sub")
        .to_string();
    if depth == 0 {
        *seq += 1;
        let inner_opts = Options {
            input: input.to_path_buf(),
            output: output.to_path_buf(),
            pid: Some(
                base_pid.wrapping_mul(1000).wrapping_add(*seq),
            ),
            ..opts.clone()
        };
        let inv = session.submit(&inner_opts, apps)?;
        pending.push((prefix.to_string(), inv));
        return Ok(TreeNode {
            name,
            output: output.to_path_buf(),
            children: Vec::new(),
            leaf: Some(pending.len() - 1),
        });
    }

    let mut subdirs: Vec<PathBuf> = fs::read_dir(input)
        .at(input)?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.is_dir())
        .collect();
    subdirs.sort();
    if subdirs.is_empty() {
        return Err(Error::EmptyInput(input.to_path_buf()));
    }

    let mut children = Vec::with_capacity(subdirs.len());
    for sub in &subdirs {
        let sub_name = sub
            .file_name()
            .and_then(|n| n.to_str())
            .unwrap_or("sub")
            .to_string();
        let child_prefix = if prefix.is_empty() {
            sub_name.clone()
        } else {
            format!("{prefix}/{sub_name}")
        };
        children.push(submit_tree(
            session,
            opts,
            apps,
            sub,
            &output.join(&sub_name),
            &child_prefix,
            depth - 1,
            base_pid,
            seq,
            pending,
        )?);
    }
    Ok(TreeNode {
        name,
        output: output.to_path_buf(),
        children,
        leaf: None,
    })
}

/// Merge the hierarchy bottom-up: a leaf contributes its inner reduce
/// output; an internal node collects its children's contributions into
/// a pid-suffixed staging dir and reduces them into `<output>/<redout>`.
fn merge_tree(
    node: &TreeNode,
    inner: &[(String, MapReduceReport)],
    outer: &dyn ReduceApp,
    redout: &str,
    base_pid: u32,
) -> Result<Option<PathBuf>> {
    if let Some(i) = node.leaf {
        return Ok(inner[i].1.redout_path.clone());
    }
    let mut parts: Vec<(String, PathBuf)> = Vec::new();
    for child in &node.children {
        if let Some(out) =
            merge_tree(child, inner, outer, redout, base_pid)?
        {
            parts.push((child.name.clone(), out));
        }
    }
    let collect_dir = node.output.join(format!(".multilevel.{base_pid}"));
    fs::create_dir_all(&collect_dir).at(&collect_dir)?;
    for (name, out) in &parts {
        let dst = collect_dir.join(format!("{name}.part"));
        fs::copy(out, &dst).at(out)?;
    }
    let out = node.output.join(redout);
    outer.reduce(&collect_dir, &out)?;
    fs::remove_dir_all(&collect_dir).ok();
    Ok(Some(out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::testutil::{ConcatReducer, CountingApp};
    use crate::scheduler::local::LocalEngine;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("llmr-ml-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn setup(tag: &str) -> (PathBuf, PathBuf) {
        let root = tmp(tag);
        let input = root.join("input");
        for (sub, n) in [("sensors-a", 3), ("sensors-b", 2)] {
            let d = input.join(sub);
            fs::create_dir_all(&d).unwrap();
            for i in 0..n {
                fs::write(d.join(format!("{sub}-{i}.txt")), format!("{i}\n"))
                    .unwrap();
            }
        }
        (input, root.join("output"))
    }

    #[test]
    fn nested_runs_one_inner_job_per_subdir() {
        let (input, output) = setup("basic");
        let opts = Options::new(&input, &output, "counting-app")
            .reducer("concat-reducer")
            .pid(70001);
        let apps = Apps {
            mapper: Arc::new(CountingApp::new()),
            reducer: Some(Arc::new(ConcatReducer)),
        };
        let eng = LocalEngine::new(2);
        let report =
            run_nested(&opts, &apps, Some(Arc::new(ConcatReducer)), &eng)
                .unwrap();
        assert_eq!(report.inner.len(), 2);
        assert_eq!(report.total_items(), 5);
        // Inner outputs land in per-subdir output dirs.
        assert!(output.join("sensors-a/sensors-a-0.txt.out").is_file());
        assert!(output.join("sensors-b/sensors-b-1.txt.out").is_file());
        // Final merge exists and contains all mapped lines.
        let final_out = report.final_out.unwrap();
        let text = fs::read_to_string(final_out).unwrap();
        assert_eq!(text.matches("#mapped").count(), 5);
        // Merge staging is scratch: cleaned up after the reduce.
        assert!(!output.join(".multilevel.70001").exists());
        assert!(report.elapsed() > Duration::ZERO);
    }

    #[test]
    fn nested_without_outer_reducer() {
        let (input, output) = setup("noouter");
        let opts = Options::new(&input, &output, "counting-app").pid(70002);
        let apps = Apps {
            mapper: Arc::new(CountingApp::new()),
            reducer: None,
        };
        let eng = LocalEngine::new(1);
        let report = run_nested(&opts, &apps, None, &eng).unwrap();
        assert!(report.final_out.is_none());
        assert_eq!(report.inner.len(), 2);
    }

    #[test]
    fn three_level_hierarchy_merges_to_one_file() {
        // input/site-X/sensor-Y/*.txt, depth 2.
        let root = tmp("deep");
        let input = root.join("input");
        for site in ["site-a", "site-b"] {
            for sensor in ["s1", "s2"] {
                let d = input.join(site).join(sensor);
                fs::create_dir_all(&d).unwrap();
                for i in 0..2 {
                    fs::write(
                        d.join(format!("{site}-{sensor}-{i}.txt")),
                        format!("{i}\n"),
                    )
                    .unwrap();
                }
            }
        }
        let opts = Options::new(&input, root.join("output"), "counting-app")
            .reducer("concat-reducer")
            .pid(70010);
        let apps = Apps {
            mapper: Arc::new(CountingApp::new()),
            reducer: Some(Arc::new(ConcatReducer)),
        };
        let eng = LocalEngine::new(2);
        let report = run_nested_depth(
            &opts,
            &apps,
            Some(Arc::new(ConcatReducer)),
            &eng,
            2,
        )
        .unwrap();
        assert_eq!(report.inner.len(), 4, "2 sites x 2 sensors");
        assert_eq!(report.total_items(), 8);
        let final_out = report.final_out.unwrap();
        let text = fs::read_to_string(&final_out).unwrap();
        assert_eq!(text.matches("#mapped").count(), 8);
        // Inner names carry the hierarchy path.
        assert!(report.inner.iter().any(|(n, _)| n == "site-a/s1"));
        // Every intermediate level merged too.
        assert!(root
            .join("output/site-a")
            .join("llmapreduce.out")
            .is_file());
    }

    #[test]
    fn depth_zero_is_plain_run() {
        let root = tmp("flat0");
        let input = root.join("input");
        fs::create_dir_all(&input).unwrap();
        fs::write(input.join("a.txt"), "a").unwrap();
        let opts =
            Options::new(&input, root.join("out"), "counting-app").pid(70011);
        let apps = Apps {
            mapper: Arc::new(CountingApp::new()),
            reducer: None,
        };
        let eng = LocalEngine::new(1);
        let r = run_nested_depth(&opts, &apps, None, &eng, 0).unwrap();
        assert_eq!(r.total_items(), 1);
    }

    #[test]
    fn empty_hierarchy_is_error() {
        let root = tmp("empty");
        let input = root.join("input");
        fs::create_dir_all(&input).unwrap();
        let opts = Options::new(&input, root.join("out"), "m").pid(70003);
        let apps = Apps {
            mapper: Arc::new(CountingApp::new()),
            reducer: None,
        };
        let eng = LocalEngine::new(1);
        assert!(run_nested(&opts, &apps, None, &eng).is_err());
    }

    #[test]
    fn derived_pids_stay_distinct_on_wide_and_deep_trees() {
        // ≥10 subdirectories used to collide under the old
        // base*100 + depth*10 + k derivation; the leaf-sequence scheme
        // cannot (distinct seq per leaf).  Checked through the visible
        // artifact: every inner invocation keeps its own .MAPRED dir.
        let root = tmp("widepids");
        let input = root.join("input");
        for k in 0..12 {
            let d = input.join(format!("dir-{k:02}"));
            fs::create_dir_all(&d).unwrap();
            fs::write(d.join("x.txt"), "x").unwrap();
        }
        let opts = Options::new(&input, root.join("output"), "counting-app")
            .keep(true)
            .workdir(&root)
            .pid(70020);
        let apps = Apps {
            mapper: Arc::new(CountingApp::new()),
            reducer: None,
        };
        let eng = LocalEngine::new(2);
        let report = run_nested(&opts, &apps, None, &eng).unwrap();
        assert_eq!(report.inner.len(), 12);
        let kept = fs::read_dir(&root)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| {
                e.file_name().to_string_lossy().starts_with(".MAPRED.")
            })
            .count();
        assert_eq!(kept, 12, "one distinct .MAPRED.<pid> per leaf");
    }
}
