//! MIMO support: the SISO→MIMO morph (§II-B) and its overhead model.
//!
//! "The --apptype=mimo option will generate the input files for the
//! modified map application that will read the input file with the
//! multiple lines of input/output filename pairs."
//!
//! This module owns (a) the pair-list *reader* — the Rust analogue of the
//! `while ischar(tline)` loop in Fig 11 that MIMO-capable external apps
//! use, and (b) the closed-form overhead model the paper's §IV discusses,
//! used by the benches to sanity-check the measured curves.

use std::path::{Path, PathBuf};
use std::time::Duration;

use crate::error::{Error, IoContext, Result};

/// Parse a MIMO pair-list file (`input_<N>`): one "input output" pair per
/// line, whitespace separated — the format Fig 11's MATLAB wrapper and
/// Fig 17's Java wrapper read.
pub fn parse_pair_list(path: &Path) -> Result<Vec<(PathBuf, PathBuf)>> {
    let text = std::fs::read_to_string(path).at(path)?;
    let mut pairs = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let mut it = line.split_whitespace();
        let (Some(input), Some(output)) = (it.next(), it.next()) else {
            return Err(Error::Format {
                kind: "mimo pair list",
                path: path.to_path_buf(),
                reason: format!("line {}: expected 'input output'", lineno + 1),
            });
        };
        if it.next().is_some() {
            return Err(Error::Format {
                kind: "mimo pair list",
                path: path.to_path_buf(),
                reason: format!("line {}: trailing tokens", lineno + 1),
            });
        }
        pairs.push((PathBuf::from(input), PathBuf::from(output)));
    }
    Ok(pairs)
}

/// Closed-form per-task overhead (the y-axis of Fig 18) for the three
/// launch options, given per-launch startup cost, per-task scheduler
/// dispatch cost, and `files_per_task`.
///
/// * DEFAULT — every file is its own array task: per *file* the scheduler
///   dispatches once and the app starts once.  Normalized per "task at
///   width np" it is `files_per_task × (dispatch + startup)`.
/// * BLOCK — np tasks, one dispatch each, app starts per file:
///   `dispatch + files_per_task × startup`.
/// * MIMO — np tasks, one dispatch, one start-up: `dispatch + startup`.
#[derive(Debug, Clone, Copy)]
pub struct OverheadModel {
    pub startup: Duration,
    pub dispatch: Duration,
}

/// The three launch options compared in §IV.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LaunchOption {
    Default,
    Block,
    Mimo,
}

impl LaunchOption {
    pub fn as_str(&self) -> &'static str {
        match self {
            LaunchOption::Default => "DEFAULT",
            LaunchOption::Block => "BLOCK",
            LaunchOption::Mimo => "MIMO",
        }
    }

    pub const ALL: [LaunchOption; 3] = [
        LaunchOption::Default,
        LaunchOption::Block,
        LaunchOption::Mimo,
    ];
}

impl OverheadModel {
    /// Overhead attributed to one width-np "task slot" processing
    /// `files_per_task` files.
    pub fn per_task_overhead(
        &self,
        option: LaunchOption,
        files_per_task: usize,
    ) -> Duration {
        let f = files_per_task as u32;
        match option {
            LaunchOption::Default => (self.dispatch + self.startup) * f,
            LaunchOption::Block => self.dispatch + self.startup * f,
            LaunchOption::Mimo => self.dispatch + self.startup,
        }
    }

    /// Predicted job elapsed time with `np` concurrent tasks over
    /// `nfiles` files of `per_item` compute each (serial dispatch cost
    /// simplified into the per-task overhead).
    pub fn elapsed(
        &self,
        option: LaunchOption,
        nfiles: usize,
        np: usize,
        per_item: Duration,
    ) -> Duration {
        let files_per_task = nfiles.div_ceil(np);
        self.per_task_overhead(option, files_per_task)
            + per_item * files_per_task as u32
    }

    /// Fig 19's speed-up: DEFAULT at np=1 over `option` at np.
    pub fn speedup(
        &self,
        option: LaunchOption,
        nfiles: usize,
        np: usize,
        per_item: Duration,
    ) -> f64 {
        let base = self
            .elapsed(LaunchOption::Default, nfiles, 1, per_item)
            .as_secs_f64();
        base / self.elapsed(option, nfiles, np, per_item).as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::fs;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("llmr-mimo-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn parse_pair_list_roundtrip() {
        let d = tmp("parse");
        let p = d.join("input_1");
        fs::write(&p, "in/a.ppm out/a.ppm.gray\nin/b.ppm out/b.ppm.gray\n")
            .unwrap();
        let pairs = parse_pair_list(&p).unwrap();
        assert_eq!(pairs.len(), 2);
        assert_eq!(pairs[0].0, PathBuf::from("in/a.ppm"));
        assert_eq!(pairs[1].1, PathBuf::from("out/b.ppm.gray"));
    }

    #[test]
    fn parse_skips_blank_lines() {
        let d = tmp("blank");
        let p = d.join("input_1");
        fs::write(&p, "\na b\n\nc d\n\n").unwrap();
        assert_eq!(parse_pair_list(&p).unwrap().len(), 2);
    }

    #[test]
    fn parse_rejects_bad_lines() {
        let d = tmp("bad");
        let p = d.join("input_1");
        fs::write(&p, "only-one-token\n").unwrap();
        assert!(parse_pair_list(&p).is_err());
        fs::write(&p, "a b c\n").unwrap();
        let err = parse_pair_list(&p).unwrap_err().to_string();
        assert!(err.contains("trailing"), "{err}");
    }

    #[test]
    fn overhead_ordering_matches_fig18() {
        let m = OverheadModel {
            startup: Duration::from_millis(100),
            dispatch: Duration::from_millis(20),
        };
        // With many files per task: DEFAULT > BLOCK >> MIMO.
        let f = 64;
        let d = m.per_task_overhead(LaunchOption::Default, f);
        let b = m.per_task_overhead(LaunchOption::Block, f);
        let mi = m.per_task_overhead(LaunchOption::Mimo, f);
        assert!(d > b, "{d:?} {b:?}");
        assert!(b > mi * 10, "{b:?} {mi:?}");
        // At one file per task all three converge (§IV: "the results of
        // all three options will converge at the same point").
        let d1 = m.per_task_overhead(LaunchOption::Default, 1);
        let b1 = m.per_task_overhead(LaunchOption::Block, 1);
        let m1 = m.per_task_overhead(LaunchOption::Mimo, 1);
        assert_eq!(d1, b1);
        assert_eq!(b1, m1);
    }

    #[test]
    fn mimo_overhead_flat_in_np() {
        let m = OverheadModel {
            startup: Duration::from_millis(100),
            dispatch: Duration::from_millis(20),
        };
        let nfiles = 512usize;
        let o_at = |np: usize| {
            m.per_task_overhead(LaunchOption::Mimo, nfiles.div_ceil(np))
        };
        assert_eq!(o_at(1), o_at(256), "MIMO per-task overhead flat");
        // While BLOCK's falls with np.
        let b_at = |np: usize| {
            m.per_task_overhead(LaunchOption::Block, nfiles.div_ceil(np))
        };
        assert!(b_at(1) > b_at(256) * 100);
    }

    #[test]
    fn speedup_curve_shape_matches_fig19() {
        let m = OverheadModel {
            startup: Duration::from_millis(100),
            dispatch: Duration::from_millis(10),
        };
        let per_item = Duration::from_millis(50);
        let nfiles = 512usize;
        for np in [1usize, 4, 16, 64, 256] {
            let s_def = m.speedup(LaunchOption::Default, nfiles, np, per_item);
            let s_blk = m.speedup(LaunchOption::Block, nfiles, np, per_item);
            let s_mimo = m.speedup(LaunchOption::Mimo, nfiles, np, per_item);
            // MIMO best, BLOCK slightly better than DEFAULT (§IV).
            assert!(s_mimo > s_blk, "np={np}");
            assert!(s_blk >= s_def, "np={np}");
        }
        // Speed-up grows with np for every option.
        assert!(
            m.speedup(LaunchOption::Mimo, nfiles, 256, per_item)
                > m.speedup(LaunchOption::Mimo, nfiles, 1, per_item)
        );
    }
}
