//! The planner: input files × options → per-task assignments.
//!
//! Encodes §II/§III-A's rules:
//!
//! * no `--np`, no `--ndata` → **DEFAULT**: one array task per input file;
//! * `--np=N` → N array tasks, each takes a block (or cyclic slice) of
//!   the inputs — "only 100 array tasks are created and each array task
//!   will process a block of the total input data";
//! * `--ndata=K` → K files per task, **overriding** `--np`;
//! * the task count must respect the scheduler dialect's array limit
//!   (Grid Engine defaults to 75,000).
//!
//! Output naming follows §III-A: `<input name><delimiter><ext>`, placed in
//! the output directory (mirroring the input subtree when `--subdir`).

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::mapreduce::distribution::distribute;
use crate::options::{AppType, Options};
use crate::scheduler::dialect::Dialect;
use crate::workdir::scan::InputFile;

/// One planned array task: which (input, output) pairs it owns.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PlannedTask {
    /// 1-based array task id (`$SGE_TASK_ID`).
    pub task_id: usize,
    pub pairs: Vec<(PathBuf, PathBuf)>,
}

/// The complete plan for one LLMapReduce invocation.
#[derive(Debug, Clone)]
pub struct Plan {
    pub tasks: Vec<PlannedTask>,
    /// Launch protocol each task uses.
    pub apptype: AppType,
    /// Total number of input files planned.
    pub nfiles: usize,
}

impl Plan {
    /// Files per task, max over tasks (the paper's "block size").
    pub fn max_files_per_task(&self) -> usize {
        self.tasks.iter().map(|t| t.pairs.len()).max().unwrap_or(0)
    }

    /// Total application launches the plan implies.
    pub fn total_launches(&self) -> usize {
        match self.apptype {
            AppType::Siso => self.nfiles,
            AppType::Mimo | AppType::Spmd => {
                self.tasks.iter().filter(|t| !t.pairs.is_empty()).count()
            }
        }
    }

    /// Output files owned by the task at index `idx` — what an overlapped
    /// partial-reduce stage consumes the moment that mapper task lands.
    pub fn task_outputs(
        &self,
        idx: usize,
    ) -> Vec<PathBuf> {
        self.tasks
            .get(idx)
            .map(|t| t.pairs.iter().map(|(_, out)| out.clone()).collect())
            .unwrap_or_default()
    }

    /// Identity task-dependency edges for the overlapped reduce: partial
    /// task *i* becomes eligible when map task *i* completes (the
    /// task-granularity analogue of Fig 1's job dependency).
    pub fn overlap_edges(&self) -> Vec<(usize, usize)> {
        (0..self.tasks.len()).map(|i| (i, i)).collect()
    }
}

/// Decide the number of array tasks for `nfiles` inputs under `opts`,
/// enforcing the dialect's array limit.
pub fn task_count(
    nfiles: usize,
    opts: &Options,
    dialect: &dyn Dialect,
) -> Result<usize> {
    let requested = if let Some(ndata) = opts.ndata {
        // --ndata overrides --np (§II).
        nfiles.div_ceil(ndata)
    } else if let Some(np) = opts.np {
        np.min(nfiles.max(1))
    } else {
        // DEFAULT: task per file (Fig 7: "each input image file ...
        // becomes an array task").
        nfiles
    };
    let requested = requested.max(1);
    let limit = dialect.max_array_tasks();
    if requested > limit {
        // The paper's remedy is "--np can be used"; DEFAULT mode with too
        // many files is a hard error pointing the user at --np.
        if opts.np.is_none() && opts.ndata.is_none() {
            return Err(Error::ArrayLimit {
                requested,
                limit,
                dialect: dialect.kind().as_str().to_string(),
            });
        }
        return Err(Error::ArrayLimit {
            requested,
            limit,
            dialect: dialect.kind().as_str().to_string(),
        });
    }
    Ok(requested)
}

/// Pack `nitems` items into contiguous batches of `items_per_task` —
/// the SPMD morph's ganging step (`--spmd` / `--items-per-task`).  The
/// returned ranges index the input list in order: concatenated they
/// cover `0..nitems` exactly once with no gaps, overlaps, or
/// reordering, every range but possibly the last holds exactly
/// `items_per_task` items, and the last holds the (non-empty) tail.
/// A zero `items_per_task` is treated as 1 so arbitrary caller input
/// cannot produce unbounded batches.
pub fn pack_batches(
    nitems: usize,
    items_per_task: usize,
) -> Vec<std::ops::Range<usize>> {
    let step = items_per_task.max(1);
    (0..nitems.div_ceil(step))
        .map(|b| b * step..((b + 1) * step).min(nitems))
        .collect()
}

/// Build the output path for one input file.
///
/// With `--subdir` the input's relative directory is replicated below the
/// output root (§II-A, Fig 3); otherwise outputs are flat.
///
/// Hot path (called once per input file, 43,580 times at Table II scale):
/// the flat case assembles root/name<delim><ext> into one pre-sized
/// buffer instead of chaining `output_name` + `join` allocations —
/// measured 2.2x on the plan/43580x256 micro bench (EXPERIMENTS.md
/// §Perf).
pub fn output_path(
    opts: &Options,
    output_root: &Path,
    input: &InputFile,
) -> PathBuf {
    let file_name = input.file_name();
    if opts.subdir {
        let name = opts.output_name(file_name);
        return match input.relative.parent() {
            Some(parent) if !parent.as_os_str().is_empty() => {
                output_root.join(parent).join(name)
            }
            _ => output_root.join(name),
        };
    }
    // Flat case: one allocation, exact capacity.
    let root = output_root.as_os_str();
    let mut buf = std::ffi::OsString::with_capacity(
        root.len()
            + 1
            + file_name.len()
            + opts.delimiter.len()
            + opts.ext.len(),
    );
    buf.push(root);
    buf.push("/");
    buf.push(file_name);
    buf.push(&opts.delimiter);
    buf.push(&opts.ext);
    PathBuf::from(buf)
}

/// Produce the full plan: task count, distribution, output naming.
pub fn plan(
    files: &[InputFile],
    opts: &Options,
    dialect: &dyn Dialect,
) -> Result<Plan> {
    let pair_of = |i: usize| {
        let input = &files[i];
        (
            input.path.clone(),
            output_path(opts, &opts.output, input),
        )
    };
    // SPMD morph: ignore --np/--ndata task shaping and pack contiguous
    // batches of --items-per-task items, one persistent-instance task
    // per batch.  Batches are always contiguous (order is part of the
    // byte-identity contract), so --distribution does not apply.
    if opts.spmd_enabled() {
        let batches =
            pack_batches(files.len(), opts.effective_items_per_task());
        let limit = dialect.max_array_tasks();
        if batches.len() > limit {
            return Err(Error::ArrayLimit {
                requested: batches.len(),
                limit,
                dialect: dialect.kind().as_str().to_string(),
            });
        }
        let tasks = if batches.is_empty() {
            // Keep the non-spmd invariant of at least one (empty) task.
            vec![PlannedTask {
                task_id: 1,
                pairs: Vec::new(),
            }]
        } else {
            batches
                .into_iter()
                .enumerate()
                .map(|(t, range)| PlannedTask {
                    task_id: t + 1,
                    pairs: range.map(pair_of).collect(),
                })
                .collect()
        };
        return Ok(Plan {
            tasks,
            apptype: AppType::Spmd,
            nfiles: files.len(),
        });
    }
    let ntasks = task_count(files.len(), opts, dialect)?;
    // Block assignments are contiguous ranges — build them directly and
    // skip materializing the index vectors (perf: see EXPERIMENTS.md
    // §Perf iteration 2).
    let tasks = match opts.distribution {
        crate::options::Distribution::Block => {
            let base = files.len() / ntasks;
            let rem = files.len() % ntasks;
            let mut next = 0usize;
            (0..ntasks)
                .map(|t| {
                    let size = base + usize::from(t < rem);
                    let pairs = (next..next + size).map(pair_of).collect();
                    next += size;
                    PlannedTask {
                        task_id: t + 1,
                        pairs,
                    }
                })
                .collect()
        }
        _ => distribute(files.len(), ntasks, opts.distribution)
            .into_iter()
            .enumerate()
            .map(|(t, idxs)| PlannedTask {
                task_id: t + 1,
                pairs: idxs.into_iter().map(pair_of).collect(),
            })
            .collect(),
    };
    Ok(Plan {
        tasks,
        apptype: opts.apptype,
        nfiles: files.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::options::{Distribution, Options, SchedulerKind};
    use crate::scheduler::dialect::dialect_for;

    fn files(n: usize) -> Vec<InputFile> {
        (0..n)
            .map(|i| InputFile {
                path: PathBuf::from(format!("/in/f{i:04}.dat")),
                relative: PathBuf::from(format!("f{i:04}.dat")),
            })
            .collect()
    }

    fn ge() -> Box<dyn Dialect + Send + Sync> {
        dialect_for(SchedulerKind::GridEngine)
    }

    #[test]
    fn default_mode_task_per_file() {
        let opts = Options::new("/in", "/out", "m");
        let p = plan(&files(6), &opts, ge().as_ref()).unwrap();
        assert_eq!(p.tasks.len(), 6);
        assert!(p.tasks.iter().all(|t| t.pairs.len() == 1));
        assert_eq!(p.total_launches(), 6);
    }

    #[test]
    fn np_caps_tasks() {
        // Fig 7 -> Fig 10 transition: --np=2 over 6 images.
        let opts = Options::new("/in", "/out", "m").np(2);
        let p = plan(&files(6), &opts, ge().as_ref()).unwrap();
        assert_eq!(p.tasks.len(), 2);
        assert_eq!(p.max_files_per_task(), 3);
    }

    #[test]
    fn ndata_overrides_np() {
        let opts = Options::new("/in", "/out", "m").np(2).ndata(5);
        let p = plan(&files(12), &opts, ge().as_ref()).unwrap();
        // ceil(12/5) = 3 tasks, not 2.
        assert_eq!(p.tasks.len(), 3);
        assert!(p.max_files_per_task() <= 5);
    }

    #[test]
    fn np_larger_than_files_clamps() {
        let opts = Options::new("/in", "/out", "m").np(100);
        let p = plan(&files(4), &opts, ge().as_ref()).unwrap();
        assert_eq!(p.tasks.len(), 4);
    }

    #[test]
    fn array_limit_enforced() {
        let opts = Options::new("/in", "/out", "m"); // DEFAULT
        let err =
            task_count(80_000, &opts, ge().as_ref()).unwrap_err();
        assert!(matches!(err, Error::ArrayLimit { limit: 75_000, .. }));
        // With --np the same input fits.
        let opts = opts.np(256);
        assert_eq!(task_count(80_000, &opts, ge().as_ref()).unwrap(), 256);
    }

    #[test]
    fn slurm_limit_tighter() {
        let d = dialect_for(SchedulerKind::Slurm);
        let opts = Options::new("/in", "/out", "m");
        assert!(task_count(5_000, &opts, d.as_ref()).is_err());
        assert_eq!(
            task_count(5_000, &opts.np(512), d.as_ref()).unwrap(),
            512
        );
    }

    #[test]
    fn output_names_follow_fig9() {
        // Fig 9: output = input name + ".out" in the output dir.
        let opts = Options::new("/in", "/out", "m");
        let p = plan(&files(2), &opts, ge().as_ref()).unwrap();
        assert_eq!(
            p.tasks[0].pairs[0].1,
            PathBuf::from("/out/f0000.dat.out")
        );
    }

    #[test]
    fn ext_and_delimiter_respected() {
        // Fig 10: --ext=gray -> ".gray".
        let opts = Options::new("/in", "/out", "m").ext("gray");
        let p = plan(&files(1), &opts, ge().as_ref()).unwrap();
        assert!(p.tasks[0].pairs[0].1.to_str().unwrap().ends_with("f0000.dat.gray"));
    }

    #[test]
    fn subdir_replicates_tree() {
        let fs = vec![
            InputFile {
                path: PathBuf::from("/in/x/a.dat"),
                relative: PathBuf::from("x/a.dat"),
            },
            InputFile {
                path: PathBuf::from("/in/x/y/b.dat"),
                relative: PathBuf::from("x/y/b.dat"),
            },
        ];
        let opts = Options::new("/in", "/out", "m").subdir(true);
        let p = plan(&fs, &opts, ge().as_ref()).unwrap();
        let outs: Vec<_> = p
            .tasks
            .iter()
            .flat_map(|t| t.pairs.iter().map(|(_, o)| o.clone()))
            .collect();
        assert!(outs.contains(&PathBuf::from("/out/x/a.dat.out")));
        assert!(outs.contains(&PathBuf::from("/out/x/y/b.dat.out")));
    }

    #[test]
    fn without_subdir_outputs_flat() {
        let fs = vec![InputFile {
            path: PathBuf::from("/in/x/a.dat"),
            relative: PathBuf::from("x/a.dat"),
        }];
        let opts = Options::new("/in", "/out", "m");
        let p = plan(&fs, &opts, ge().as_ref()).unwrap();
        assert_eq!(p.tasks[0].pairs[0].1, PathBuf::from("/out/a.dat.out"));
    }

    #[test]
    fn cyclic_distribution_in_plan() {
        let opts = Options::new("/in", "/out", "m")
            .np(3)
            .distribution(Distribution::Cyclic);
        let p = plan(&files(7), &opts, ge().as_ref()).unwrap();
        let t1: Vec<_> = p.tasks[0]
            .pairs
            .iter()
            .map(|(i, _)| i.to_str().unwrap().to_string())
            .collect();
        assert_eq!(t1, vec!["/in/f0000.dat", "/in/f0003.dat", "/in/f0006.dat"]);
    }

    #[test]
    fn overlap_helpers_mirror_task_layout() {
        let opts = Options::new("/in", "/out", "m").np(3);
        let p = plan(&files(6), &opts, ge().as_ref()).unwrap();
        assert_eq!(p.overlap_edges(), vec![(0, 0), (1, 1), (2, 2)]);
        let outs = p.task_outputs(0);
        assert_eq!(outs.len(), 2, "6 files over 3 block tasks");
        assert_eq!(outs[0], PathBuf::from("/out/f0000.dat.out"));
        assert!(p.task_outputs(99).is_empty(), "out of range is empty");
    }

    #[test]
    fn pack_batches_covers_exactly_once_in_order() {
        assert_eq!(pack_batches(0, 4), Vec::<std::ops::Range<usize>>::new());
        assert_eq!(pack_batches(10, 4), vec![0..4, 4..8, 8..10]);
        assert_eq!(pack_batches(4, 4), vec![0..4]);
        assert_eq!(pack_batches(3, 100), vec![0..3], "N > items: one batch");
        assert_eq!(
            pack_batches(3, 1),
            vec![0..1, 1..2, 2..3],
            "N=1 degenerates to per-item tasks"
        );
        assert_eq!(pack_batches(5, 0), pack_batches(5, 1), "0 clamps to 1");
    }

    #[test]
    fn spmd_plan_packs_batches_and_sets_mode() {
        let opts = Options::new("/in", "/out", "m").items_per_task(4);
        let p = plan(&files(10), &opts, ge().as_ref()).unwrap();
        assert_eq!(p.apptype, AppType::Spmd);
        assert_eq!(p.tasks.len(), 3, "ceil(10/4)");
        assert_eq!(p.tasks[0].pairs.len(), 4);
        assert_eq!(p.tasks[2].pairs.len(), 2, "uneven tail");
        assert_eq!(p.total_launches(), 3, "one launch per batch");
        // Item order preserved across batches.
        let inputs: Vec<_> = p
            .tasks
            .iter()
            .flat_map(|t| t.pairs.iter().map(|(i, _)| i.clone()))
            .collect();
        let expected: Vec<_> =
            files(10).iter().map(|f| f.path.clone()).collect();
        assert_eq!(inputs, expected);
    }

    #[test]
    fn spmd_overrides_np_and_apptype() {
        // --np and --apptype shape nothing once ganging is on; the batch
        // size is the only knob.
        let opts = Options::new("/in", "/out", "m")
            .np(2)
            .apptype(AppType::Mimo)
            .spmd(true); // default batch size 16
        let p = plan(&files(40), &opts, ge().as_ref()).unwrap();
        assert_eq!(p.apptype, AppType::Spmd);
        assert_eq!(p.tasks.len(), 3, "ceil(40/16), not --np=2");
    }

    #[test]
    fn spmd_plan_with_no_files_keeps_one_empty_task() {
        let opts = Options::new("/in", "/out", "m").spmd(true);
        let p = plan(&files(0), &opts, ge().as_ref()).unwrap();
        assert_eq!(p.tasks.len(), 1);
        assert!(p.tasks[0].pairs.is_empty());
        assert_eq!(p.total_launches(), 0, "empty batch never launches");
    }

    #[test]
    fn spmd_respects_array_limit() {
        let d = dialect_for(SchedulerKind::Slurm);
        let opts = Options::new("/in", "/out", "m").items_per_task(1);
        let err = plan(&files(5_000), &opts, d.as_ref()).unwrap_err();
        assert!(matches!(err, Error::ArrayLimit { .. }));
    }

    #[test]
    fn mimo_launch_accounting() {
        let opts = Options::new("/in", "/out", "m")
            .np(4)
            .apptype(AppType::Mimo);
        let p = plan(&files(16), &opts, ge().as_ref()).unwrap();
        assert_eq!(p.total_launches(), 4);
        let siso = Options::new("/in", "/out", "m").np(4);
        let p2 = plan(&files(16), &siso, ge().as_ref()).unwrap();
        assert_eq!(p2.total_launches(), 16);
    }
}
