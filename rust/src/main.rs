//! The `llmapreduce` command-line interface.
//!
//! Mirrors the paper's one-line usage (Figs 7/10/15/16) plus the
//! reproduction's experiment drivers:
//!
//! ```text
//! llmapreduce run --mapper=imageconvert --input=in --output=out [Fig 2 opts]
//! llmapreduce gen-data images|corpus|matrices --dir=... [--count=N]
//! llmapreduce bench table1|table2|fig18|fig19|spmd|all
//! llmapreduce inspect            # artifact manifest + environment
//! ```

use std::path::PathBuf;
use std::time::Duration;

use llmapreduce::apps::image::ImageConvertApp;
use llmapreduce::apps::matmul::MatmulChainApp;
use llmapreduce::apps::registry::{resolve_mapper, resolve_reducer};
use llmapreduce::bench::experiments::{
    fig18_19_sweep, spmd_amortization_virtual, spmd_bench_json,
    table1_java, table1_matlab, table2, PAPER_WIDTHS,
};
use llmapreduce::error::{Error, Result};
use llmapreduce::mapreduce::{run, Apps};
use llmapreduce::metrics::report::{
    overhead_series, recovery_summary, speedup_series, sweep_csv,
    worker_attribution,
};
use llmapreduce::options::{Options, WorkerOptions};
use llmapreduce::prelude::{LocalEngine, Manifest};
use llmapreduce::scheduler::cost::Calibration;
use llmapreduce::scheduler::remote::{run_worker, WorkerConfig};
use llmapreduce::workload::images::generate_images;
use llmapreduce::workload::matrices::generate_matrix_lists;
use llmapreduce::workload::text::generate_corpus;
use llmapreduce::workload::trace::TraceParams;

const USAGE: &str = "\
llmapreduce — LLMapReduce (HPEC'16) on a Rust + JAX + Pallas stack

USAGE:
  llmapreduce run [Fig 2 options]        run one map-reduce job
  llmapreduce resume <.MAPRED.PID dir>   resume a crashed job from its
                                         journal (re-runs only tasks
                                         without a completion record)
  llmapreduce dlq reprocess <.MAPRED.PID dir>
                                         resubmit dead-lettered tasks
  llmapreduce status <.MAPRED.PID dir> [--json]
                                         offline progress report from a
                                         workdir (status.json, or journal
                                         replay after SIGKILL)
  llmapreduce top <.MAPRED.PID dir | HOST:PORT>
                  [--interval-ms=N] [--frames=N]
                                         live periodic view: queue depth,
                                         per-job and per-worker counts,
                                         p50/p95/p99 task latency
  llmapreduce trace <.MAPRED.PID dir> [--out=FILE] [--format=chrome|json]
                                         per-task span timelines from the
                                         journal: critical-path report on
                                         stdout + a Chrome trace-event file
                                         (default <dir>/trace.json; open in
                                         Perfetto or chrome://tracing)
  llmapreduce worker --connect=H:P       join a remote coordinator
  llmapreduce gen-data <kind> [opts]     generate synthetic workloads
  llmapreduce bench <experiment>         regenerate a paper table/figure
  llmapreduce inspect                    show artifacts + environment
  llmapreduce help

RUN OPTIONS (Fig 2 of the paper):
  --np=N --ndata=K --input=DIR --output=DIR --mapper=APP [--reducer=APP]
  --redout=FILE --distribution=block|cyclic --subdir=true|false
  --ext=EXT --delimeter=D --exclusive=true|false --keep=true|false
  --apptype=mimo|siso|spmd --options=<raw scheduler directives>
  --scheduler=gridengine|slurm|lsf
  plus: --slots=N (engine width, default np)
        --engine=local|sim|sim-exec|remote (execution substrate)
        --listen=HOST:PORT (remote: coordinator bind, default
          127.0.0.1:7171)  --min-workers=N (remote: wait for N
          registered workers before running, default 1)
        --workdir=DIR (where .MAPRED.PID is created)
        --overlap=true|false (overlapped map->reduce: the reducer
          consumes each mapper task's output as it completes instead
          of barriering on the whole map array job; see DESIGN.md)
        --spmd[=BOOL] (gang items into batches run by one persistent
          app instance per task; see DESIGN.md §7)
        --items-per-task=N (batch size for --spmd, default 16;
          implies --spmd)
        --on-error=stop|retry|dlq|skip (what to do when a task's
          execution errors; default stop.  dlq completes the job and
          records the task in the workdir's dead-letter queue)
        --failure-threshold=F (circuit breaker: fail the whole job
          once more than fraction F of its tasks have errored;
          0.0..=1.0, default 1.0 = never)
        --telemetry[=BOOL] (event bus + status.json in the workdir;
          default on — pass --telemetry=false to switch it off)
        --trace[=BOOL] (persist per-task span timings on the journal's
          done records for `llmapreduce trace`; default on — pass
          --trace=false to shrink journal records)
        --metrics-listen=HOST:PORT (remote engine only: serve
          Prometheus text at /metrics and a JSON snapshot at /status
          while the coordinator runs; scrape live or point
          `llmapreduce top HOST:PORT` at it)
        --batch-frames[=BOOL] (remote: drain all ready tasks for a
          worker into one AssignBatch frame and overcommit its queue;
          default on — legacy workers always get frame-per-task)
        --steal[=BOOL] (remote: idle workers pull queued tasks from
          the most-backlogged peer when the central queue is dry;
          default on)
  resume/dlq also accept --slots/--engine/--listen/--min-workers
  /--metrics-listen/--batch-frames/--steal; everything else (apps,
  Fig 2 options) is restored from the journal.

WORKER (the daemon side of --engine=remote; spawn one per node):
  llmapreduce worker --connect=HOST:PORT [--slots=N] [--name=S]
                     [--heartbeat-ms=N] [--fail-after=N]
                     [--wire=json|binary]

  Built-in mappers: imageconvert, imagepipeline, matmulchain,
                    wordcount[:ignorefile]
  Any other mapper string is launched as an external command.
  Built-in reducers: wordcount-reducer, frobsum-reducer; otherwise external.

GEN-DATA:
  images   --dir=D [--count=6]   PPM images sized for the artifact
  corpus   --dir=D [--count=21]  Zipf text + textignore.txt
  matrices --dir=D [--count=512] MATLIST chain files

BENCH:
  table1 | table2 | fig18 | fig19 | spmd | all
  (spmd writes BENCH_spmd.json at the repo root)";

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn dispatch(args: &[String]) -> Result<()> {
    match args.first().map(String::as_str) {
        Some("run") => cmd_run(&args[1..]),
        Some("resume") => cmd_resume(&args[1..]),
        Some("dlq") => cmd_dlq(&args[1..]),
        Some("status") => cmd_status(&args[1..]),
        Some("top") => cmd_top(&args[1..]),
        Some("trace") => cmd_trace(&args[1..]),
        Some("worker") => cmd_worker(&args[1..]),
        Some("gen-data") => cmd_gen_data(&args[1..]),
        Some("bench") => cmd_bench(&args[1..]),
        Some("inspect") => cmd_inspect(),
        Some("help") | None => {
            println!("{USAGE}");
            Ok(())
        }
        Some(other) => Err(Error::opt(format!(
            "unknown command '{other}' (try `llmapreduce help`)"
        ))),
    }
}

/// Engine options pulled out of the `run` arg list — they select the
/// execution substrate, which the paper's Fig 2 surface never needed
/// (it had a real cluster).
#[derive(Default)]
struct EngineArgs {
    slots: Option<usize>,
    engine: Option<String>,
    listen: Option<String>,
    min_workers: Option<usize>,
    metrics_listen: Option<String>,
    batch_frames: Option<bool>,
    steal: Option<bool>,
}

/// Split `--slots` / `--engine` / `--listen` / `--min-workers` /
/// `--metrics-listen` / `--batch-frames` / `--steal` from the Fig 2
/// options.
fn split_engine_args(args: &[String]) -> (Vec<String>, EngineArgs) {
    let mut rest = Vec::new();
    let mut ea = EngineArgs::default();
    let mut it = args.iter().peekable();
    while let Some(a) = it.next() {
        if let Some(v) = a.strip_prefix("--slots=") {
            ea.slots = v.parse().ok();
        } else if a == "--slots" {
            ea.slots = it.next().and_then(|v| v.parse().ok());
        } else if let Some(v) = a.strip_prefix("--engine=") {
            ea.engine = Some(v.to_string());
        } else if a == "--engine" {
            ea.engine = it.next().cloned();
        } else if let Some(v) = a.strip_prefix("--listen=") {
            ea.listen = Some(v.to_string());
        } else if a == "--listen" {
            ea.listen = it.next().cloned();
        } else if let Some(v) = a.strip_prefix("--min-workers=") {
            ea.min_workers = v.parse().ok();
        } else if a == "--min-workers" {
            ea.min_workers = it.next().and_then(|v| v.parse().ok());
        } else if let Some(v) = a.strip_prefix("--metrics-listen=") {
            ea.metrics_listen = Some(v.to_string());
        } else if a == "--metrics-listen" {
            ea.metrics_listen = it.next().cloned();
        } else if let Some(v) = a.strip_prefix("--batch-frames=") {
            ea.batch_frames = v.parse().ok();
        } else if a == "--batch-frames" {
            ea.batch_frames = Some(true);
        } else if let Some(v) = a.strip_prefix("--steal=") {
            ea.steal = v.parse().ok();
        } else if a == "--steal" {
            ea.steal = Some(true);
        } else {
            rest.push(a.clone());
        }
    }
    (rest, ea)
}

/// Apply the `--engine`/`--listen`/`--min-workers` overrides and build
/// the engine (shared by `run`, `resume` and `dlq reprocess`).
fn engine_from(
    mut config: llmapreduce::config::Config,
    engine_args: &EngineArgs,
    width: usize,
) -> Result<Box<dyn llmapreduce::scheduler::Engine>> {
    if let Some(e) = &engine_args.engine {
        config.engine = llmapreduce::config::EngineKind::parse(e)?;
    }
    if let Some(l) = &engine_args.listen {
        config.remote.listen = l.clone();
    }
    if let Some(n) = engine_args.min_workers {
        config.remote.min_workers = n;
    }
    if let Some(m) = &engine_args.metrics_listen {
        config.telemetry.metrics_listen = Some(m.clone());
    }
    if let Some(b) = engine_args.batch_frames {
        config.remote.batch_frames = b;
    }
    if let Some(s) = engine_args.steal {
        config.remote.steal = s;
    }
    if config.engine == llmapreduce::config::EngineKind::Remote {
        println!(
            "coordinator binding {} — waiting for {} worker(s); spawn \
             them with `llmapreduce worker --connect={}`",
            config.remote.listen,
            config.remote.min_workers,
            config.remote.listen
        );
        if let Some(m) = &config.telemetry.metrics_listen {
            println!(
                "metrics endpoint on {m} — /metrics (Prometheus text), \
                 /status (JSON); watch with `llmapreduce top {m}`"
            );
        }
    }
    config.build_engine(width)
}

fn cmd_run(args: &[String]) -> Result<()> {
    let (fig2_args, engine_args) = split_engine_args(args);
    let mut opts = Options::parse_args(&fig2_args)?;

    // Config file + env defaults under explicit CLI values.
    let config = llmapreduce::config::Config::discover()?;
    config.apply_job_defaults(&mut opts);

    let mapper = resolve_mapper(&opts.mapper)?;
    let reducer = opts
        .reducer
        .as_deref()
        .map(resolve_reducer)
        .transpose()?;
    let apps = Apps { mapper, reducer };
    let width = engine_args.slots.or(opts.np).unwrap_or(4);
    let engine = engine_from(config, &engine_args, width)?;
    let report = run(&opts, &apps, engine.as_ref())?;
    println!("engine: {}", engine.name());

    println!(
        "job '{}' done: {} files, {} tasks, {} launches",
        opts.mapper,
        report.map.total_items(),
        report.plan.tasks.len(),
        report.map.total_launches()
    );
    println!(
        "  elapsed {}  (startup {}, compute {})",
        llmapreduce::util::fmt_duration(report.elapsed()),
        llmapreduce::util::fmt_duration(report.map.total_startup()),
        llmapreduce::util::fmt_duration(report.map.total_compute()),
    );
    println!(
        "  utilization {:.0}%{}",
        report.utilization() * 100.0,
        if report.overlapped {
            "  (overlapped map->reduce)"
        } else {
            ""
        }
    );
    if let Some(p) = &report.partials {
        println!(
            "  partial reduces: {} tasks consumed eagerly",
            p.tasks.len()
        );
    }
    if let Some(p) = &report.redout_path {
        println!("  reduce output: {}", p.display());
    }
    if let Some(d) = &report.mapred_dir {
        println!("  kept workdir: {}", d.display());
    }
    let dead = report.map.dead_lettered();
    if dead > 0 {
        let wd = report
            .mapred_dir
            .as_ref()
            .map(|d| d.display().to_string())
            .unwrap_or_else(|| "<workdir>".into());
        println!(
            "  dead-lettered: {dead} task(s) — inspect {wd}/dlq.jsonl, \
             resubmit with `llmapreduce dlq reprocess {wd}`"
        );
    }
    if engine.name() == "remote" {
        println!("\nper-worker attribution (map job):");
        println!("{}", worker_attribution(&report.map));
    }
    Ok(())
}

/// Shared argument parsing for `resume` / `dlq reprocess`: one workdir
/// positional plus the engine-selection flags.
fn recovery_args(
    what: &str,
    args: &[String],
) -> Result<(PathBuf, EngineArgs)> {
    let (rest, engine_args) = split_engine_args(args);
    let workdir = rest.first().ok_or_else(|| {
        Error::opt(format!(
            "{what} needs the crashed run's .MAPRED.<pid> directory"
        ))
    })?;
    if let Some(extra) = rest.get(1) {
        return Err(Error::opt(format!(
            "unexpected {what} argument '{extra}'"
        )));
    }
    Ok((PathBuf::from(workdir), engine_args))
}

/// `llmapreduce resume <workdir>`: reconstruct a crashed invocation
/// from its journal and re-run only the tasks that never completed.
fn cmd_resume(args: &[String]) -> Result<()> {
    let (workdir, engine_args) = recovery_args("resume", args)?;
    let config = llmapreduce::config::Config::discover()?;
    let width = engine_args.slots.unwrap_or(4);
    let engine = engine_from(config, &engine_args, width)?;
    let report =
        llmapreduce::mapreduce::resume(&workdir, engine.as_ref())?;
    println!(
        "resumed {}: {} task(s) already complete (skipped), {} re-run",
        workdir.display(),
        report.map.replayed,
        report.plan.tasks.len() - report.map.replayed,
    );
    if let Some(p) = &report.redout_path {
        println!("  reduce output: {}", p.display());
    }
    println!("{}", recovery_summary(&report.map));
    Ok(())
}

/// `llmapreduce dlq reprocess <workdir>`: resubmit every dead-lettered
/// task through the normal planner path and re-reduce.
fn cmd_dlq(args: &[String]) -> Result<()> {
    match args.first().map(String::as_str) {
        Some("reprocess") => {
            let (workdir, engine_args) =
                recovery_args("dlq reprocess", &args[1..])?;
            let config = llmapreduce::config::Config::discover()?;
            let width = engine_args.slots.unwrap_or(4);
            let engine = engine_from(config, &engine_args, width)?;
            let report = llmapreduce::mapreduce::dlq_reprocess(
                &workdir,
                engine.as_ref(),
            )?;
            println!(
                "reprocessed {} dead-lettered task(s) from {}",
                report.map.tasks.len(),
                workdir.display(),
            );
            let dead = report.map.dead_lettered();
            if dead > 0 {
                println!(
                    "  {dead} task(s) failed again and were re-enqueued"
                );
            }
            if let Some(p) = &report.redout_path {
                println!("  reduce output: {}", p.display());
            }
            Ok(())
        }
        _ => Err(Error::opt(
            "usage: llmapreduce dlq reprocess <.MAPRED.PID dir>",
        )),
    }
}

/// `llmapreduce status <workdir>`: offline progress report.  Folds the
/// workdir's journal when present (the same replay `resume` acts on, so
/// the counts agree even after SIGKILL), else the last `status.json`
/// snapshot the telemetry layer flushed.
fn cmd_status(args: &[String]) -> Result<()> {
    let mut workdir = None;
    let mut json = false;
    for a in args {
        match a.as_str() {
            "--json" => json = true,
            other if !other.starts_with("--") && workdir.is_none() => {
                workdir = Some(PathBuf::from(other));
            }
            other => {
                return Err(Error::opt(format!(
                    "unexpected status argument '{other}'"
                )))
            }
        }
    }
    let workdir = workdir.ok_or_else(|| {
        Error::opt("status needs a .MAPRED.<pid> directory")
    })?;
    let status = llmapreduce::telemetry::fold_workdir(&workdir)?;
    if json {
        println!("{}", status.to_string_pretty());
    } else {
        print!("{}", llmapreduce::telemetry::render_status(&status));
    }
    Ok(())
}

/// `llmapreduce top <workdir | host:port>`: periodically refreshed live
/// view.  A `host:port` target polls a coordinator's `--metrics-listen`
/// endpoint; a directory target re-folds the workdir each frame.
fn cmd_top(args: &[String]) -> Result<()> {
    let mut target = None;
    let mut interval = Duration::from_millis(1000);
    let mut frames: Option<u64> = None;
    let mut it = args.iter().peekable();
    while let Some(a) = it.next() {
        if let Some(v) = a.strip_prefix("--interval-ms=") {
            interval = Duration::from_millis(v.parse().map_err(|_| {
                Error::opt("--interval-ms needs a millisecond count")
            })?);
        } else if a == "--interval-ms" {
            let v = it.next().ok_or_else(|| {
                Error::opt("--interval-ms needs a millisecond count")
            })?;
            interval = Duration::from_millis(v.parse().map_err(|_| {
                Error::opt("--interval-ms needs a millisecond count")
            })?);
        } else if let Some(v) = a.strip_prefix("--frames=") {
            frames = Some(v.parse().map_err(|_| {
                Error::opt("--frames needs a frame count")
            })?);
        } else if a == "--frames" {
            let v = it
                .next()
                .ok_or_else(|| Error::opt("--frames needs a count"))?;
            frames = Some(v.parse().map_err(|_| {
                Error::opt("--frames needs a frame count")
            })?);
        } else if !a.starts_with("--") && target.is_none() {
            target = Some(a.clone());
        } else {
            return Err(Error::opt(format!(
                "unexpected top argument '{a}'"
            )));
        }
    }
    let target = target.ok_or_else(|| {
        Error::opt(
            "top needs a .MAPRED.<pid> directory or a coordinator's \
             --metrics-listen HOST:PORT",
        )
    })?;
    // `host:port` when it is not a directory and looks like an address;
    // everything else is treated as a workdir path.
    let as_dir = PathBuf::from(&target);
    let is_endpoint = !as_dir.is_dir() && target.contains(':');
    let mut frame = 0u64;
    loop {
        let status = if is_endpoint {
            let body = llmapreduce::telemetry::fetch(&target, "/status")?;
            llmapreduce::util::json::Json::parse(&body).map_err(|e| {
                Error::opt(format!("bad /status payload from {target}: {e}"))
            })?
        } else {
            llmapreduce::telemetry::fold_workdir(&as_dir)?
        };
        let looping = frames != Some(1);
        if looping {
            // Clear screen + home, like top(1).
            print!("\x1b[2J\x1b[H");
        }
        print!("{}", llmapreduce::telemetry::render_top(&status));
        use std::io::Write as _;
        let _ = std::io::stdout().flush();
        frame += 1;
        if let Some(n) = frames {
            if frame >= n {
                break;
            }
        }
        std::thread::sleep(interval);
    }
    Ok(())
}

/// `llmapreduce trace <workdir>`: assemble per-task span timelines
/// from the journal (works after SIGKILL, like `status`), print the
/// critical-path report, and export a Chrome trace-event file.
fn cmd_trace(args: &[String]) -> Result<()> {
    let mut workdir = None;
    let mut out: Option<PathBuf> = None;
    let mut format = String::from("chrome");
    let mut it = args.iter().peekable();
    while let Some(a) = it.next() {
        if let Some(v) = a.strip_prefix("--out=") {
            out = Some(PathBuf::from(v));
        } else if a == "--out" {
            let v = it.next().ok_or_else(|| {
                Error::opt("--out needs a file path")
            })?;
            out = Some(PathBuf::from(v));
        } else if let Some(v) = a.strip_prefix("--format=") {
            format = v.to_string();
        } else if a == "--format" {
            let v = it.next().ok_or_else(|| {
                Error::opt("--format needs chrome or json")
            })?;
            format = v.clone();
        } else if !a.starts_with("--") && workdir.is_none() {
            workdir = Some(PathBuf::from(a));
        } else {
            return Err(Error::opt(format!(
                "unexpected trace argument '{a}'"
            )));
        }
    }
    let workdir = workdir.ok_or_else(|| {
        Error::opt("trace needs a .MAPRED.<pid> directory")
    })?;
    let trace = llmapreduce::telemetry::trace_workdir(&workdir)?;
    let doc = match format.as_str() {
        "chrome" => llmapreduce::telemetry::chrome_trace(&trace),
        "json" => llmapreduce::telemetry::trace_json(&trace),
        other => {
            return Err(Error::opt(format!(
                "unknown trace format '{other}' (chrome or json)"
            )))
        }
    };
    let out = out.unwrap_or_else(|| workdir.join("trace.json"));
    std::fs::write(&out, doc.to_string_compact())
        .map_err(|e| Error::io(out.clone(), e))?;
    print!("{}", llmapreduce::telemetry::render_trace_report(&trace));
    println!(
        "\nwrote {} ({format} format{})",
        out.display(),
        if format == "chrome" {
            " — open in Perfetto or chrome://tracing"
        } else {
            ""
        }
    );
    Ok(())
}

/// `llmapreduce worker`: the daemon side of `--engine=remote`.  Blocks
/// until the coordinator shuts the fleet down.
fn cmd_worker(args: &[String]) -> Result<()> {
    let w = WorkerOptions::parse_args(args)?;
    let mut config = WorkerConfig::new(w.connect.clone()).slots(w.slots);
    if let Some(name) = &w.name {
        config = config.name(name.clone());
    }
    config.heartbeat_interval = Duration::from_millis(w.heartbeat_ms);
    config.fail_after = w.fail_after;
    config = config.wire(w.wire);
    println!(
        "worker '{}' joining {} with {} slot(s), preferring {} framing",
        config.name,
        config.connect,
        config.slots,
        config.wire.as_str()
    );
    run_worker(config)?;
    println!("worker done (coordinator shut down)");
    Ok(())
}

fn cmd_gen_data(args: &[String]) -> Result<()> {
    let kind = args
        .first()
        .ok_or_else(|| Error::opt("gen-data needs a kind"))?
        .clone();
    let mut dir = PathBuf::from("input");
    let mut count = None;
    let mut seed = 42u64;
    for a in &args[1..] {
        if let Some(v) = a.strip_prefix("--dir=") {
            dir = PathBuf::from(v);
        } else if let Some(v) = a.strip_prefix("--count=") {
            count = v.parse().ok();
        } else if let Some(v) = a.strip_prefix("--seed=") {
            seed = v.parse().unwrap_or(42);
        } else {
            return Err(Error::opt(format!("unknown gen-data arg '{a}'")));
        }
    }
    match kind.as_str() {
        "images" => {
            let (h, w) = match Manifest::discover()
                .and_then(|m| Ok(ImageConvertApp::new(&m)?.image_shape()))
            {
                Ok(shape) => shape,
                Err(_) => (256, 256),
            };
            let n = count.unwrap_or(6);
            generate_images(&dir, n, h, w, seed)?;
            println!("wrote {n} {h}x{w} PPM images to {}", dir.display());
        }
        "corpus" => {
            let n = count.unwrap_or(21);
            let (_, ignore) = generate_corpus(&dir, n, 2_000, 500, seed)?;
            println!(
                "wrote {n} docs + {} to {}",
                ignore.file_name().unwrap().to_string_lossy(),
                dir.display()
            );
        }
        "matrices" => {
            let (l, n) = match Manifest::discover()
                .and_then(|m| Ok(MatmulChainApp::new(&m)?.static_shape()))
            {
                Ok(shape) => shape,
                Err(_) => (4, 128),
            };
            let c = count.unwrap_or(512);
            generate_matrix_lists(&dir, c, l, n, seed)?;
            println!(
                "wrote {c} MATLIST files ({l} chains of {n}x{n}) to {}",
                dir.display()
            );
        }
        other => {
            return Err(Error::opt(format!("unknown gen-data kind '{other}'")))
        }
    }
    Ok(())
}

fn tmp_bench_dir(tag: &str) -> Result<PathBuf> {
    let d = std::env::temp_dir()
        .join(format!("llmr-bench-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).map_err(|e| Error::io(d.clone(), e))?;
    Ok(d)
}

fn cmd_bench(args: &[String]) -> Result<()> {
    let which = args.first().map(String::as_str).unwrap_or("all");
    let run_t1 = which == "table1" || which == "all";
    let run_t2 = which == "table2" || which == "all";
    let run_f18 = which == "fig18" || which == "all";
    let run_f19 = which == "fig19" || which == "all";
    let run_spmd = which == "spmd" || which == "all";
    if !(run_t1 || run_t2 || run_f18 || run_f19 || run_spmd) {
        return Err(Error::opt(format!("unknown experiment '{which}'")));
    }

    if run_t1 {
        println!("== TABLE I: speed up with toy examples ==\n");
        // MATLAB row: imageconvert over 6 images, 2 array tasks.
        match Manifest::discover().and_then(|m| ImageConvertApp::new(&m)) {
            Ok(app) => {
                let d = tmp_bench_dir("t1m")?;
                let (h, w) = app.image_shape();
                generate_images(&d.join("input"), 6, h, w, 1)?;
                let eng = LocalEngine::new(2);
                let r = table1_matlab(
                    &d.join("input"),
                    &d.join("output"),
                    app,
                    &eng,
                )?;
                println!("{}", r.table());
                println!("paper: 2.41x   measured: {:.2}x\n", r.speedup());
            }
            Err(e) => println!("(skipping MATLAB row: {e})\n"),
        }
        // Java row: wordcount over 21 files, 3 tasks, cyclic.
        let d = tmp_bench_dir("t1j")?;
        let eng = LocalEngine::new(3);
        // JVM boot stand-in: 5ms against ~1.5ms/file of counting gives the
        // paper's startup:compute regime (speed-up ≈ 2.85 at 7 files/task).
        let r = table1_java(&d, Duration::from_millis(5), &eng)?;
        println!("{}", r.table());
        println!("paper: 2.85x   measured: {:.2}x\n", r.speedup());
    }

    if run_t2 {
        println!("== TABLE II: real-world trace (43,580 files, 256 tasks) ==\n");
        let r = table2(TraceParams::table2())?;
        println!("{}", r.table());
        println!("paper: 11.57x   simulated: {:.2}x\n", r.speedup());
    }

    if run_f18 || run_f19 {
        let hint = calibrated_hint();
        println!(
            "calibrated costs: startup={}, per-file={}\n",
            llmapreduce::util::fmt_duration(hint.startup),
            llmapreduce::util::fmt_duration(hint.per_item)
        );
        // Dispatch latency 1ms: array-task launches on real schedulers
        // are cheap relative to application start-up; 10ms would make the
        // serialized dispatcher the bottleneck past np=64, a regime the
        // paper's cluster does not show.
        let sweep = fig18_19_sweep(
            512,
            &PAPER_WIDTHS,
            hint,
            Duration::from_millis(1),
        )?;
        if run_f18 {
            println!("== FIG 18: overhead per array task ==\n");
            println!("{}", overhead_series(&sweep));
        }
        if run_f19 {
            println!("== FIG 19: speed-up vs DEFAULT@1 ==\n");
            println!("{}", speedup_series(&sweep));
        }
        let csv_path = std::env::temp_dir().join("llmr-fig18-19.csv");
        std::fs::write(&csv_path, sweep_csv(&sweep))
            .map_err(|e| Error::io(csv_path.clone(), e))?;
        println!("csv: {}", csv_path.display());
    }

    if run_spmd {
        println!("== SPMD: launch-overhead amortization ==\n");
        // Fixed virtual costs so the artifact is byte-reproducible:
        // 64 items, 128ms startup, 10ms/item (see DESIGN.md §7).
        let hint = llmapreduce::apps::CostHint {
            startup: Duration::from_millis(128),
            per_item: Duration::from_millis(10),
        };
        let pts = spmd_amortization_virtual(64, hint, &[1, 4, 16, 64])?;
        for p in &pts {
            println!(
                "  {:>8}  N={:<3} launches={:<3} per-item launch overhead {}",
                p.mode,
                p.items_per_task,
                p.launches,
                llmapreduce::util::fmt_duration(p.per_item_launch_overhead)
            );
        }
        let doc = spmd_bench_json("sim-virtual", 64, hint, &pts);
        let path = llmapreduce::bench::artifact_path("BENCH_spmd.json");
        std::fs::write(&path, doc.to_string_pretty())
            .map_err(|e| Error::io(path.clone(), e))?;
        println!("\njson: {}", path.display());
    }
    Ok(())
}

/// Calibrate the Fig 18/19 cost model against the real matmul app when
/// artifacts are present; fall back to representative constants.
fn calibrated_hint() -> llmapreduce::apps::CostHint {
    let fallback = llmapreduce::apps::CostHint {
        startup: Duration::from_millis(30),
        per_item: Duration::from_millis(3),
    };
    let Ok(manifest) = Manifest::discover() else {
        return fallback;
    };
    let Ok(app) = MatmulChainApp::new(&manifest) else {
        return fallback;
    };
    let Ok(dir) = tmp_bench_dir("calib") else {
        return fallback;
    };
    let (l, n) = app.static_shape();
    let Ok(paths) = generate_matrix_lists(&dir, 4, l, n, 3) else {
        return fallback;
    };
    let pairs: Vec<_> = paths
        .iter()
        .map(|p| (p.clone(), p.with_extension("mat.out")))
        .collect();
    match Calibration::measure(app.as_ref(), &pairs, 3) {
        Ok(cal) => cal.hint,
        Err(_) => fallback,
    }
}

fn cmd_inspect() -> Result<()> {
    println!("llmapreduce inspect");
    match Manifest::discover() {
        Ok(m) => {
            println!("artifacts: {}", m.dir.display());
            for e in &m.entries {
                let shapes: Vec<String> = e
                    .inputs
                    .iter()
                    .map(|i| format!("{:?}:{}", i.shape, i.dtype))
                    .collect();
                println!("  {:<18} {}", e.name, shapes.join(", "));
            }
        }
        Err(e) => println!("artifacts: NOT FOUND ({e})"),
    }
    match llmapreduce::runtime::global_client() {
        Ok(c) => println!(
            "pjrt: platform={} devices={}",
            c.platform_name(),
            c.device_count()
        ),
        Err(e) => println!("pjrt: UNAVAILABLE ({e})"),
    }
    Ok(())
}
