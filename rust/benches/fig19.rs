//! `cargo bench --bench fig19` — regenerates Fig 19: speed-up of job
//! elapsed time vs DEFAULT at one process, for DEFAULT / BLOCK / MIMO
//! across np ∈ {1..256} (512 files, §IV parameters).
//!
//! Expected shape: MIMO consistently best; BLOCK slightly above DEFAULT;
//! speed-ups grow with np until the workload's parallelism is exhausted.

use std::time::Duration;

use llmapreduce::apps::CostHint;
use llmapreduce::bench::experiments::{fig18_19_sweep, PAPER_WIDTHS};
use llmapreduce::metrics::report::{speedup_series, sweep_csv};

fn main() {
    // The paper's MATLAB regime: startup an order of magnitude above the
    // per-file compute (Table II pins ~11.4:1).  Fig 19's curves keep
    // rising to np=256 exactly because startup dominates.
    let hint = CostHint {
        startup: Duration::from_millis(11_400),
        per_item: Duration::from_millis(1_000),
    };
    println!(
        "FIG 19 — speed-up vs DEFAULT@1 (MATLAB-regime costs {:?}/{:?})\n",
        hint.startup, hint.per_item
    );
    let sweep =
        fig18_19_sweep(512, &PAPER_WIDTHS, hint, Duration::from_millis(10))
            .unwrap();
    println!("{}", speedup_series(&sweep));

    let csv = std::env::temp_dir().join("llmr-bench-fig19.csv");
    std::fs::write(&csv, sweep_csv(&sweep)).unwrap();
    println!("csv: {}", csv.display());

    // Shape assertions per the paper's §IV findings.
    let base = sweep.baseline().unwrap();
    for np in PAPER_WIDTHS {
        let d = sweep.get("DEFAULT", np).unwrap().speedup_vs(base);
        let b = sweep.get("BLOCK", np).unwrap().speedup_vs(base);
        let m = sweep.get("MIMO", np).unwrap().speedup_vs(base);
        assert!(m > b, "np={np}: MIMO best ({m:.2} vs {b:.2})");
        assert!(
            b >= d * 0.95,
            "np={np}: BLOCK >= DEFAULT ({b:.2} vs {d:.2})"
        );
    }
    // Monotone growth for MIMO across the paper's sweep.
    let mut prev = 0.0;
    for np in PAPER_WIDTHS {
        let m = sweep.get("MIMO", np).unwrap().speedup_vs(base);
        assert!(
            m > prev,
            "MIMO speed-up must grow with np (np={np}: {m:.2} <= {prev:.2})"
        );
        prev = m;
    }
    println!("shape checks: OK (MIMO > BLOCK >= DEFAULT, monotone in np)");
}
