//! `cargo bench --bench remote` — task-shipping overhead of the
//! distributed engine.
//!
//! Runs the same wordcount pipeline on the in-process `LocalEngine` and
//! on a `RemoteCoordinator` with 1, 2 and 4 localhost worker processes
//! (hosted on threads over real TCP), and reports the per-task shipping
//! overhead — assignment round-trip minus worker-measured execution —
//! next to compute time.  Every remote run must stay byte-identical to
//! the local baseline; the bench is also a correctness gate.
//!
//! A trailing SPMD section re-runs the job batch-packed (per-task N=1
//! vs ganged N=8) over a two-worker fleet: fewer, larger assignments
//! amortize shipping the same way ganged launches amortize app
//! start-up, and the merged output must stay byte-identical.
//!
//! The final section is the small-task sweep: 1,000 × ~1ms synthetic
//! tasks on a two-worker fleet, once over the legacy frame-per-task
//! line-JSON wire and once with batched binary framing.  Per-task
//! shipping must drop at least 2x — the acceptance gate for the PR-10
//! dispatch hot path.

use std::fs;
use std::path::PathBuf;
use std::time::{Duration, Instant};

use llmapreduce::bench::artifact_path;
use llmapreduce::bench::experiments::{remote_bench_json, RemotePoint};
use llmapreduce::mapreduce::{run, Apps};
use llmapreduce::metrics::report::{render_table, worker_attribution};
use llmapreduce::options::Options;
use llmapreduce::prelude::*;
use llmapreduce::scheduler::remote::WireMode;
use llmapreduce::scheduler::{JobReport, JobSpec, TaskSpec, TaskWork};
use llmapreduce::util::fmt_duration;
use llmapreduce::workload::text::generate_corpus;

const NFILES: usize = 24;
const NP: usize = 8;

fn apps() -> Result<Apps> {
    Ok(Apps {
        mapper: llmapreduce::apps::registry::resolve_mapper("wordcount")?,
        reducer: Some(llmapreduce::apps::registry::resolve_reducer(
            "wordcount-reducer",
        )?),
    })
}

fn opts(input: &PathBuf, output: PathBuf, pid: u32) -> Options {
    Options::new(input, output, "wordcount")
        .np(NP)
        .reducer("wordcount-reducer")
        .pid(pid)
}

struct Row {
    label: String,
    elapsed: Duration,
    ship_per_task: Duration,
    compute_per_task: Duration,
    bytes: Vec<u8>,
}

fn summarize(
    label: impl Into<String>,
    elapsed: Duration,
    report: &llmapreduce::mapreduce::MapReduceReport,
) -> Row {
    let n = report.map.tasks.len().max(1) as u32;
    let ship: Duration = report.map.tasks.iter().map(|t| t.shipped).sum();
    let compute: Duration =
        report.map.tasks.iter().map(|t| t.compute).sum();
    Row {
        label: label.into(),
        elapsed,
        ship_per_task: ship / n,
        compute_per_task: compute / n,
        bytes: fs::read(report.redout_path.as_ref().expect("reduced"))
            .expect("redout readable"),
    }
}

/// Timing-only row for the small-task sweep (no redout to compare).
struct SweepRow {
    label: String,
    elapsed: Duration,
    ship_per_task: Duration,
    compute_per_task: Duration,
}

/// 1,000 tasks of ~1ms of real (spinning) compute each: the shape where
/// per-frame wire cost dominates and the PR-10 hot path has to win.
fn sweep_job() -> JobSpec {
    let tasks: Vec<TaskSpec> = (0..1_000)
        .map(|i| TaskSpec {
            task_id: i + 1,
            work: TaskWork::Synthetic {
                startup: Duration::ZERO,
                per_item: Duration::from_millis(1),
                items: 1,
                launches: 1,
            },
        })
        .collect();
    JobSpec::new("small-task-sweep", tasks)
}

fn sweep_summarize(
    label: impl Into<String>,
    elapsed: Duration,
    report: &JobReport,
) -> SweepRow {
    let n = report.tasks.len().max(1) as u32;
    let ship: Duration = report.tasks.iter().map(|t| t.shipped).sum();
    let compute: Duration = report.tasks.iter().map(|t| t.compute).sum();
    SweepRow {
        label: label.into(),
        elapsed,
        ship_per_task: ship / n,
        compute_per_task: compute / n,
    }
}

/// Run the small-task sweep: local reference, legacy line-JSON
/// frame-per-task fleet, and batched-binary fleet (two workers × two
/// slots each).  Returns the three rows in that order.
fn small_task_sweep() -> Result<Vec<SweepRow>> {
    let mut out = Vec::new();
    {
        let engine = LocalEngine::new(4);
        let t0 = Instant::now();
        let report = engine.run(sweep_job())?;
        out.push(sweep_summarize(
            "sweep local (4 slots)",
            t0.elapsed(),
            &report,
        ));
    }
    for (label, legacy) in [
        ("sweep json frame-per-task (2 workers)", true),
        ("sweep batched binary (2 workers)", false),
    ] {
        // The baseline pins the pre-PR-10 wire end to end: legacy
        // workers never advertise a framing, and the coordinator knobs
        // are off so every task ships as its own line-JSON frame.  The
        // contender is the PR-10 default: negotiated binary framing,
        // batch drain, affinity and stealing all on.
        let config = if legacy {
            CoordinatorConfig {
                batch_frames: false,
                steal: false,
                ..CoordinatorConfig::default()
            }
        } else {
            CoordinatorConfig::default()
        };
        let coordinator = RemoteCoordinator::bind("127.0.0.1:0", config)?;
        let addr = coordinator.local_addr().to_string();
        let workers: Vec<_> = (0..2)
            .map(|i| {
                let mut config = WorkerConfig::new(addr.clone())
                    .name(format!("s{i}"))
                    .slots(2);
                config = if legacy {
                    config.legacy()
                } else {
                    config.wire(WireMode::Binary)
                };
                std::thread::spawn(move || run_worker(config))
            })
            .collect();
        coordinator.wait_for_workers(2, Duration::from_secs(30))?;
        let t0 = Instant::now();
        let report = coordinator.run(sweep_job())?;
        out.push(sweep_summarize(label, t0.elapsed(), &report));
        drop(coordinator);
        for w in workers {
            w.join().expect("worker thread").expect("worker clean exit");
        }
    }
    Ok(out)
}

fn main() -> Result<()> {
    let root = std::env::temp_dir()
        .join(format!("llmr-bench-remote-{}", std::process::id()));
    let _ = fs::remove_dir_all(&root);
    fs::create_dir_all(&root).map_err(|e| Error::io(root.clone(), e))?;
    let input = root.join("input");
    // generate_corpus writes textignore.txt next to (not inside) the
    // corpus dir, so the input scan sees only the docs.
    let _ = generate_corpus(&input, NFILES, 2_000, 500, 7)?;

    println!(
        "== remote engine: shipping overhead vs local ({NFILES} files, \
         np={NP}) ==\n"
    );

    let mut rows: Vec<Row> = Vec::new();

    // Local baseline at width 4 (the largest fleet below).
    {
        let engine = LocalEngine::new(4);
        let t0 = Instant::now();
        let report = run(
            &opts(&input, root.join("out-local"), 84000).workdir(&root),
            &apps()?,
            &engine,
        )?;
        rows.push(summarize("local (4 slots)", t0.elapsed(), &report));
    }

    for nworkers in [1usize, 2, 4] {
        let coordinator = RemoteCoordinator::bind(
            "127.0.0.1:0",
            CoordinatorConfig::default(),
        )?;
        let addr = coordinator.local_addr().to_string();
        let workers: Vec<_> = (0..nworkers)
            .map(|i| {
                let config = WorkerConfig::new(addr.clone())
                    .name(format!("w{i}"))
                    .slots(1);
                std::thread::spawn(move || run_worker(config))
            })
            .collect();
        coordinator.wait_for_workers(nworkers, Duration::from_secs(30))?;
        let t0 = Instant::now();
        let report = run(
            &opts(
                &input,
                root.join(format!("out-remote-{nworkers}")),
                84100 + nworkers as u32,
            )
            .workdir(&root),
            &apps()?,
            &coordinator,
        )?;
        let elapsed = t0.elapsed();
        if nworkers == 4 {
            println!("per-worker attribution (4-worker map job):");
            println!("{}", worker_attribution(&report.map));
        }
        rows.push(summarize(
            format!("remote ({nworkers} worker(s))"),
            elapsed,
            &report,
        ));
        drop(coordinator);
        for w in workers {
            w.join().expect("worker thread").expect("worker clean exit");
        }
    }

    // SPMD ganging over the fleet: the same job batch-packed at N=1
    // (per-task) and N=8 (ganged) on two workers.  The planner ships
    // spmd-mode tasks over the wire; the rows join the byte-identity
    // gate below like every other configuration.
    {
        let coordinator = RemoteCoordinator::bind(
            "127.0.0.1:0",
            CoordinatorConfig::default(),
        )?;
        let addr = coordinator.local_addr().to_string();
        let workers: Vec<_> = (0..2)
            .map(|i| {
                let config = WorkerConfig::new(addr.clone())
                    .name(format!("g{i}"))
                    .slots(1);
                std::thread::spawn(move || run_worker(config))
            })
            .collect();
        coordinator.wait_for_workers(2, Duration::from_secs(30))?;
        for (label, n) in [
            ("remote spmd per-task (2 workers)", 1usize),
            ("remote spmd ganged N=8 (2 workers)", 8),
        ] {
            let t0 = Instant::now();
            let report = run(
                &opts(
                    &input,
                    root.join(format!("out-ganged-{n}")),
                    84200 + n as u32,
                )
                .items_per_task(n)
                .workdir(&root),
                &apps()?,
                &coordinator,
            )?;
            let elapsed = t0.elapsed();
            let launches: usize =
                report.map.tasks.iter().map(|t| t.launches).sum();
            println!(
                "{label}: {launches} launches over {} map tasks",
                report.map.tasks.len()
            );
            rows.push(summarize(label, elapsed, &report));
        }
        drop(coordinator);
        for w in workers {
            w.join().expect("worker thread").expect("worker clean exit");
        }
    }
    println!();

    let baseline = rows[0].bytes.clone();
    for r in &rows {
        assert_eq!(
            r.bytes, baseline,
            "{}: output must be byte-identical to local",
            r.label
        );
    }

    let base_elapsed = rows[0].elapsed;
    let table_rows: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.label.clone(),
                fmt_duration(r.elapsed),
                fmt_duration(r.ship_per_task),
                fmt_duration(r.compute_per_task),
                format!(
                    "{:.2}",
                    base_elapsed.as_secs_f64()
                        / r.elapsed.as_secs_f64().max(1e-12)
                ),
            ]
        })
        .collect();
    println!(
        "{}",
        render_table(
            &[
                "engine",
                "makespan",
                "ship/task",
                "compute/task",
                "vs local"
            ],
            &table_rows
        )
    );
    println!(
        "all {} configurations produced byte-identical wordcount output",
        rows.len()
    );

    // Small-task sweep: the dispatch hot path, measured.  1k × ~1ms
    // synthetic tasks; the batched-binary wire must ship each task at
    // least 2x cheaper than the legacy frame-per-task line-JSON wire.
    println!("\n== small-task sweep (1,000 × ~1ms synthetic tasks) ==\n");
    let sweep = small_task_sweep()?;
    let sweep_base = sweep[0].elapsed;
    let sweep_table: Vec<Vec<String>> = sweep
        .iter()
        .map(|r| {
            vec![
                r.label.clone(),
                fmt_duration(r.elapsed),
                fmt_duration(r.ship_per_task),
                fmt_duration(r.compute_per_task),
                format!(
                    "{:.2}",
                    sweep_base.as_secs_f64()
                        / r.elapsed.as_secs_f64().max(1e-12)
                ),
            ]
        })
        .collect();
    println!(
        "{}",
        render_table(
            &[
                "engine",
                "makespan",
                "ship/task",
                "compute/task",
                "vs local"
            ],
            &sweep_table
        )
    );
    let json_ship = sweep[1].ship_per_task;
    let bin_ship = sweep[2].ship_per_task;
    assert!(
        bin_ship * 2 <= json_ship,
        "batched binary framing must ship small tasks at least 2x \
         cheaper than line-JSON per-task: json={json_ship:?} \
         binary={bin_ship:?}"
    );
    println!(
        "batched binary ships {:.1}x cheaper per task than \
         json-per-task",
        json_ship.as_secs_f64() / bin_ship.as_secs_f64().max(1e-12)
    );

    let points: Vec<RemotePoint> = rows
        .iter()
        .map(|r| RemotePoint {
            label: r.label.clone(),
            makespan: r.elapsed,
            ship_per_task: r.ship_per_task,
            compute_per_task: r.compute_per_task,
            speedup_vs_local: base_elapsed.as_secs_f64()
                / r.elapsed.as_secs_f64().max(1e-12),
        })
        .collect();
    let mut points = points;
    points.extend(sweep.iter().map(|r| RemotePoint {
        label: r.label.clone(),
        makespan: r.elapsed,
        ship_per_task: r.ship_per_task,
        compute_per_task: r.compute_per_task,
        speedup_vs_local: sweep_base.as_secs_f64()
            / r.elapsed.as_secs_f64().max(1e-12),
    }));
    let doc = remote_bench_json("cargo-bench-remote", &points);
    let path = artifact_path("BENCH_remote.json");
    fs::write(&path, doc.to_string_pretty())
        .map_err(|e| Error::io(path.clone(), e))?;
    println!("json: {}", path.display());

    let _ = fs::remove_dir_all(&root);
    Ok(())
}
