//! `cargo bench --bench remote` — task-shipping overhead of the
//! distributed engine.
//!
//! Runs the same wordcount pipeline on the in-process `LocalEngine` and
//! on a `RemoteCoordinator` with 1, 2 and 4 localhost worker processes
//! (hosted on threads over real TCP), and reports the per-task shipping
//! overhead — assignment round-trip minus worker-measured execution —
//! next to compute time.  Every remote run must stay byte-identical to
//! the local baseline; the bench is also a correctness gate.
//!
//! A trailing SPMD section re-runs the job batch-packed (per-task N=1
//! vs ganged N=8) over a two-worker fleet: fewer, larger assignments
//! amortize shipping the same way ganged launches amortize app
//! start-up, and the merged output must stay byte-identical.

use std::fs;
use std::path::PathBuf;
use std::time::{Duration, Instant};

use llmapreduce::bench::artifact_path;
use llmapreduce::bench::experiments::{remote_bench_json, RemotePoint};
use llmapreduce::mapreduce::{run, Apps};
use llmapreduce::metrics::report::{render_table, worker_attribution};
use llmapreduce::options::Options;
use llmapreduce::prelude::*;
use llmapreduce::util::fmt_duration;
use llmapreduce::workload::text::generate_corpus;

const NFILES: usize = 24;
const NP: usize = 8;

fn apps() -> Result<Apps> {
    Ok(Apps {
        mapper: llmapreduce::apps::registry::resolve_mapper("wordcount")?,
        reducer: Some(llmapreduce::apps::registry::resolve_reducer(
            "wordcount-reducer",
        )?),
    })
}

fn opts(input: &PathBuf, output: PathBuf, pid: u32) -> Options {
    Options::new(input, output, "wordcount")
        .np(NP)
        .reducer("wordcount-reducer")
        .pid(pid)
}

struct Row {
    label: String,
    elapsed: Duration,
    ship_per_task: Duration,
    compute_per_task: Duration,
    bytes: Vec<u8>,
}

fn summarize(
    label: impl Into<String>,
    elapsed: Duration,
    report: &llmapreduce::mapreduce::MapReduceReport,
) -> Row {
    let n = report.map.tasks.len().max(1) as u32;
    let ship: Duration = report.map.tasks.iter().map(|t| t.shipped).sum();
    let compute: Duration =
        report.map.tasks.iter().map(|t| t.compute).sum();
    Row {
        label: label.into(),
        elapsed,
        ship_per_task: ship / n,
        compute_per_task: compute / n,
        bytes: fs::read(report.redout_path.as_ref().expect("reduced"))
            .expect("redout readable"),
    }
}

fn main() -> Result<()> {
    let root = std::env::temp_dir()
        .join(format!("llmr-bench-remote-{}", std::process::id()));
    let _ = fs::remove_dir_all(&root);
    fs::create_dir_all(&root).map_err(|e| Error::io(root.clone(), e))?;
    let input = root.join("input");
    // generate_corpus writes textignore.txt next to (not inside) the
    // corpus dir, so the input scan sees only the docs.
    let _ = generate_corpus(&input, NFILES, 2_000, 500, 7)?;

    println!(
        "== remote engine: shipping overhead vs local ({NFILES} files, \
         np={NP}) ==\n"
    );

    let mut rows: Vec<Row> = Vec::new();

    // Local baseline at width 4 (the largest fleet below).
    {
        let engine = LocalEngine::new(4);
        let t0 = Instant::now();
        let report = run(
            &opts(&input, root.join("out-local"), 84000).workdir(&root),
            &apps()?,
            &engine,
        )?;
        rows.push(summarize("local (4 slots)", t0.elapsed(), &report));
    }

    for nworkers in [1usize, 2, 4] {
        let coordinator = RemoteCoordinator::bind(
            "127.0.0.1:0",
            CoordinatorConfig::default(),
        )?;
        let addr = coordinator.local_addr().to_string();
        let workers: Vec<_> = (0..nworkers)
            .map(|i| {
                let config = WorkerConfig::new(addr.clone())
                    .name(format!("w{i}"))
                    .slots(1);
                std::thread::spawn(move || run_worker(config))
            })
            .collect();
        coordinator.wait_for_workers(nworkers, Duration::from_secs(30))?;
        let t0 = Instant::now();
        let report = run(
            &opts(
                &input,
                root.join(format!("out-remote-{nworkers}")),
                84100 + nworkers as u32,
            )
            .workdir(&root),
            &apps()?,
            &coordinator,
        )?;
        let elapsed = t0.elapsed();
        if nworkers == 4 {
            println!("per-worker attribution (4-worker map job):");
            println!("{}", worker_attribution(&report.map));
        }
        rows.push(summarize(
            format!("remote ({nworkers} worker(s))"),
            elapsed,
            &report,
        ));
        drop(coordinator);
        for w in workers {
            w.join().expect("worker thread").expect("worker clean exit");
        }
    }

    // SPMD ganging over the fleet: the same job batch-packed at N=1
    // (per-task) and N=8 (ganged) on two workers.  The planner ships
    // spmd-mode tasks over the wire; the rows join the byte-identity
    // gate below like every other configuration.
    {
        let coordinator = RemoteCoordinator::bind(
            "127.0.0.1:0",
            CoordinatorConfig::default(),
        )?;
        let addr = coordinator.local_addr().to_string();
        let workers: Vec<_> = (0..2)
            .map(|i| {
                let config = WorkerConfig::new(addr.clone())
                    .name(format!("g{i}"))
                    .slots(1);
                std::thread::spawn(move || run_worker(config))
            })
            .collect();
        coordinator.wait_for_workers(2, Duration::from_secs(30))?;
        for (label, n) in [
            ("remote spmd per-task (2 workers)", 1usize),
            ("remote spmd ganged N=8 (2 workers)", 8),
        ] {
            let t0 = Instant::now();
            let report = run(
                &opts(
                    &input,
                    root.join(format!("out-ganged-{n}")),
                    84200 + n as u32,
                )
                .items_per_task(n)
                .workdir(&root),
                &apps()?,
                &coordinator,
            )?;
            let elapsed = t0.elapsed();
            let launches: usize =
                report.map.tasks.iter().map(|t| t.launches).sum();
            println!(
                "{label}: {launches} launches over {} map tasks",
                report.map.tasks.len()
            );
            rows.push(summarize(label, elapsed, &report));
        }
        drop(coordinator);
        for w in workers {
            w.join().expect("worker thread").expect("worker clean exit");
        }
    }
    println!();

    let baseline = rows[0].bytes.clone();
    for r in &rows {
        assert_eq!(
            r.bytes, baseline,
            "{}: output must be byte-identical to local",
            r.label
        );
    }

    let base_elapsed = rows[0].elapsed;
    let table_rows: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.label.clone(),
                fmt_duration(r.elapsed),
                fmt_duration(r.ship_per_task),
                fmt_duration(r.compute_per_task),
                format!(
                    "{:.2}",
                    base_elapsed.as_secs_f64()
                        / r.elapsed.as_secs_f64().max(1e-12)
                ),
            ]
        })
        .collect();
    println!(
        "{}",
        render_table(
            &[
                "engine",
                "makespan",
                "ship/task",
                "compute/task",
                "vs local"
            ],
            &table_rows
        )
    );
    println!(
        "all {} configurations produced byte-identical wordcount output",
        rows.len()
    );

    let points: Vec<RemotePoint> = rows
        .iter()
        .map(|r| RemotePoint {
            label: r.label.clone(),
            makespan: r.elapsed,
            ship_per_task: r.ship_per_task,
            compute_per_task: r.compute_per_task,
            speedup_vs_local: base_elapsed.as_secs_f64()
                / r.elapsed.as_secs_f64().max(1e-12),
        })
        .collect();
    let doc = remote_bench_json("cargo-bench-remote", &points);
    let path = artifact_path("BENCH_remote.json");
    fs::write(&path, doc.to_string_pretty())
        .map_err(|e| Error::io(path.clone(), e))?;
    println!("json: {}", path.display());

    let _ = fs::remove_dir_all(&root);
    Ok(())
}
