//! `cargo bench --bench ablation` — design-choice ablations DESIGN.md
//! calls out:
//!
//! 1. **block vs cyclic distribution** under three file-cost patterns —
//!    the §II claim that cyclic "improve[s] initial load balancing";
//! 2. **dispatch latency sensitivity** — where the serialized scheduler
//!    dispatcher starts to dominate DEFAULT mode (the regime boundary
//!    discussed around Fig 18).

use std::time::Duration;

use llmapreduce::apps::CostHint;
use llmapreduce::bench::experiments::{ablation_distribution, fig18_19_sweep};

fn main() {
    println!("ABLATION 1 — block vs cyclic under cost skew (256 files, np=8)\n");
    let cells =
        ablation_distribution(256, 8, Duration::from_millis(10), 42).unwrap();
    println!(
        "{:<10} {:<8} {:>12} {:>12}",
        "pattern", "dist", "makespan", "straggler"
    );
    for c in &cells {
        println!(
            "{:<10} {:<8} {:>12} {:>12}",
            c.pattern,
            c.distribution.as_str(),
            llmapreduce::util::fmt_duration(c.makespan),
            llmapreduce::util::fmt_duration(c.straggler),
        );
    }
    // Assertions: cyclic within 10% on uniform, >=20% better on sorted.
    let get = |p: &str, d: llmapreduce::options::Distribution| {
        cells
            .iter()
            .find(|c| c.pattern == p && c.distribution == d)
            .unwrap()
            .makespan
            .as_secs_f64()
    };
    use llmapreduce::options::Distribution::{Block, Cyclic};
    assert!(get("sorted", Block) > get("sorted", Cyclic) * 1.2);
    println!("\nshape check: cyclic wins on sorted costs (the paper's load-balancing claim)\n");

    println!("ABLATION 2 — dispatch latency sensitivity (512 files, np=64, DEFAULT vs MIMO)\n");
    let hint = CostHint {
        startup: Duration::from_millis(100),
        per_item: Duration::from_millis(10),
    };
    println!("{:<14} {:>12} {:>12} {:>9}", "dispatch", "DEFAULT", "MIMO", "ratio");
    for ms in [0u64, 1, 10, 50, 200] {
        let sweep =
            fig18_19_sweep(512, &[64], hint, Duration::from_millis(ms))
                .unwrap();
        let d = sweep.get("DEFAULT", 64).unwrap().elapsed;
        let m = sweep.get("MIMO", 64).unwrap().elapsed;
        println!(
            "{:<14} {:>12} {:>12} {:>8.1}x",
            format!("{ms}ms"),
            llmapreduce::util::fmt_duration(d),
            llmapreduce::util::fmt_duration(m),
            d.as_secs_f64() / m.as_secs_f64(),
        );
    }
    println!("\n(growing dispatch cost widens the DEFAULT/MIMO gap: every per-file\n task pays the dispatcher, MIMO pays it np times total)");

    println!("\nABLATION 3 — cluster utilization vs np (512 files, MATLAB regime)\n");
    // Utilization = busy slot-time / (makespan x slots).  MIMO keeps the
    // cluster busy; SISO burns slot-time on repeated start-ups that ARE
    // "busy" but useless — so we also show the useful fraction
    // (compute-only utilization), which is the number that collapses.
    let heavy = CostHint {
        startup: Duration::from_millis(11_400),
        per_item: Duration::from_millis(1_000),
    };
    println!(
        "{:<6} {:>14} {:>14} {:>16} {:>16}",
        "np", "BLOCK util", "MIMO util", "BLOCK useful", "MIMO useful"
    );
    for np in [1usize, 16, 64, 256] {
        let sweep =
            fig18_19_sweep(512, &[np], heavy, Duration::from_millis(10))
                .unwrap();
        let cell = |opt: &str| {
            let m = sweep.get(opt, np).unwrap();
            let busy = (m.total_startup + m.total_compute).as_secs_f64();
            let useful = m.total_compute.as_secs_f64();
            let slot_time = m.elapsed.as_secs_f64() * np as f64;
            (busy / slot_time, useful / slot_time)
        };
        let (bu, bf) = cell("BLOCK");
        let (mu, mf) = cell("MIMO");
        println!(
            "{:<6} {:>13.0}% {:>13.0}% {:>15.0}% {:>15.0}%",
            np,
            bu * 100.0,
            mu * 100.0,
            bf * 100.0,
            mf * 100.0
        );
    }
    println!("\n(BLOCK looks 'busy' but ~90% of its slot-time is start-up churn;\n MIMO's slot-time is almost all useful compute)");
}
