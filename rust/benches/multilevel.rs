//! `cargo bench --bench multilevel` — serial vs concurrent multi-level
//! fan-out.
//!
//! The seed's nested path ran one inner LLMapReduce per subdirectory
//! *strictly serially*: each branch submitted and waited before the next
//! branch started, so a hierarchy never used more engine slots than one
//! inner pipeline could.  The handle-based API submits every branch up
//! front (`Session::submit` returns pre-execution) and waits afterwards,
//! so all branches share the slot cap concurrently — the same
//! barrier-removal argument as `--overlap`, one level up.
//!
//! This bench runs the same 6-branch hierarchy both ways on one
//! `LocalEngine` shape and checks two things: the concurrent wall clock
//! is measurably lower, and the final merged reduce output is
//! byte-identical.  Tasks sleep rather than spin so the comparison is
//! honest on a single-core container.

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use llmapreduce::apps::{MapApp, MapInstance, ReduceApp};
use llmapreduce::mapreduce::multilevel::run_nested;
use llmapreduce::prelude::*;

const BRANCHES: usize = 6;
const FILES_PER_BRANCH: usize = 4;
const SLEEP_MS: u64 = 40;
const NP: usize = 2; // inner tasks per branch: serial path uses ≤ NP slots
const SLOTS: usize = 4;

/// Mapper that sleeps `SLEEP_MS` per file and emits a deterministic,
/// input-derived record.
struct SleepMapApp;

struct SleepMapInstance;

impl MapApp for SleepMapApp {
    fn name(&self) -> &str {
        "sleep-map"
    }

    fn startup(&self) -> Result<Box<dyn MapInstance>> {
        Ok(Box::new(SleepMapInstance))
    }
}

impl MapInstance for SleepMapInstance {
    fn process(&mut self, input: &Path, output: &Path) -> Result<()> {
        std::thread::sleep(Duration::from_millis(SLEEP_MS));
        let name = input
            .file_name()
            .map(|n| n.to_string_lossy().to_string())
            .unwrap_or_default();
        fs::write(output, format!("{name}:mapped\n"))
            .map_err(|e| Error::io(output.to_path_buf(), e))
    }
}

/// Deterministic reducer: sorted concat of the directory (excluding its
/// own output).
struct SortedConcat;

impl ReduceApp for SortedConcat {
    fn name(&self) -> &str {
        "sorted-concat"
    }

    fn reduce(&self, dir: &Path, out: &Path) -> Result<()> {
        let mut files: Vec<PathBuf> = fs::read_dir(dir)
            .map_err(|e| Error::io(dir.to_path_buf(), e))?
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| p.is_file() && *p != *out)
            .collect();
        files.sort();
        let mut merged = String::new();
        for f in &files {
            merged.push_str(
                &fs::read_to_string(f).map_err(|e| Error::io(f.clone(), e))?,
            );
        }
        fs::write(out, merged).map_err(|e| Error::io(out.to_path_buf(), e))
    }
}

fn apps() -> Apps {
    Apps {
        mapper: Arc::new(SleepMapApp),
        reducer: Some(Arc::new(SortedConcat)),
    }
}

/// The seed's serial semantics: one blocking inner run per branch, then
/// the same collect-and-merge the nested path performs.
fn serial_nested(root: &Path, input: &Path) -> Result<(String, Duration)> {
    let engine = LocalEngine::new(SLOTS);
    let apps = apps();
    let output = root.join("out-serial");
    let collect = root.join("serial-collect");
    fs::create_dir_all(&collect)
        .map_err(|e| Error::io(collect.clone(), e))?;
    let t0 = Instant::now();
    let mut subdirs: Vec<PathBuf> = fs::read_dir(input)
        .map_err(|e| Error::io(input.to_path_buf(), e))?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.is_dir())
        .collect();
    subdirs.sort();
    for (k, sub) in subdirs.iter().enumerate() {
        let name = sub
            .file_name()
            .map(|n| n.to_string_lossy().to_string())
            .unwrap_or_default();
        let opts = Options::new(sub, output.join(&name), "sleep-map")
            .np(NP)
            .reducer("sorted-concat")
            .workdir(root)
            .pid(83100 + k as u32);
        let report = run(&opts, &apps, &engine)?;
        let redout = report.redout_path.expect("inner reducer ran");
        let dst = collect.join(format!("{name}.part"));
        fs::copy(&redout, &dst).map_err(|e| Error::io(dst.clone(), e))?;
    }
    let out = output.join("llmapreduce.out");
    SortedConcat.reduce(&collect, &out)?;
    let elapsed = t0.elapsed();
    let _ = fs::remove_dir_all(&collect);
    let text =
        fs::read_to_string(&out).map_err(|e| Error::io(out.clone(), e))?;
    Ok((text, elapsed))
}

/// The handle-based path: every branch submitted before any wait.
fn concurrent_nested(
    root: &Path,
    input: &Path,
) -> Result<(String, Duration)> {
    let engine = LocalEngine::new(SLOTS);
    let apps = apps();
    let opts = Options::new(input, root.join("out-concurrent"), "sleep-map")
        .np(NP)
        .reducer("sorted-concat")
        .workdir(root)
        .pid(83200);
    let t0 = Instant::now();
    let report =
        run_nested(&opts, &apps, Some(Arc::new(SortedConcat)), &engine)?;
    let elapsed = t0.elapsed();
    let out = report.final_out.expect("outer reducer ran");
    let text =
        fs::read_to_string(&out).map_err(|e| Error::io(out.clone(), e))?;
    Ok((text, elapsed))
}

fn main() -> Result<()> {
    let root = std::env::temp_dir()
        .join(format!("llmr-bench-multilevel-{}", std::process::id()));
    let _ = fs::remove_dir_all(&root);
    let input = root.join("input");
    for b in 0..BRANCHES {
        let d = input.join(format!("branch-{b}"));
        fs::create_dir_all(&d).map_err(|e| Error::io(d.clone(), e))?;
        for i in 0..FILES_PER_BRANCH {
            let f = d.join(format!("b{b}-{i:02}.txt"));
            fs::write(&f, "x\n").map_err(|e| Error::io(f.clone(), e))?;
        }
    }

    println!("== multi-level fan-out: serial seed path vs concurrent ==");
    println!(
        "{BRANCHES} branches x {FILES_PER_BRANCH} files x {SLEEP_MS}ms, \
         np={NP}, slots={SLOTS}\n"
    );
    let (serial_text, serial_elapsed) = serial_nested(&root, &input)?;
    let (conc_text, conc_elapsed) = concurrent_nested(&root, &input)?;

    assert_eq!(
        serial_text, conc_text,
        "concurrent fan-out must produce the identical final reduce output"
    );
    let speedup = serial_elapsed.as_secs_f64()
        / conc_elapsed.as_secs_f64().max(1e-12);
    println!(
        "serial     {}   (each branch waits for the previous)",
        llmapreduce::util::fmt_duration(serial_elapsed)
    );
    println!(
        "concurrent {}   (all branches submitted up front)",
        llmapreduce::util::fmt_duration(conc_elapsed)
    );
    println!("speed-up   {speedup:.2}x, identical final output");
    assert!(
        speedup > 1.2,
        "concurrent multi-level fan-out should beat the serial path \
         ({speedup:.2}x)"
    );
    let _ = fs::remove_dir_all(&root);
    Ok(())
}
