//! `cargo bench --bench table1` — regenerates Table I of the paper.
//!
//! Real end-to-end jobs on the local engine: the image-conversion app
//! (XLA compile = application start-up) over 6 images / 2 tasks, and the
//! word-count app (spin = JVM boot) over 21 files / 3 tasks.  BLOCK vs
//! MIMO speed-up is the reported number; the paper's values are 2.41x
//! (MATLAB) and 2.85x (Java).

use std::time::Duration;

use llmapreduce::bench::experiments::{table1_java, table1_matlab};
use llmapreduce::prelude::*;
use llmapreduce::workload::images::generate_images;

fn tmp(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir()
        .join(format!("llmr-bench-table1-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn main() {
    println!("TABLE I — speed up with toy examples (paper: 2.41x / 2.85x)\n");

    match Manifest::discover().and_then(|m| ImageConvertApp::new(&m)) {
        Ok(app) => {
            let d = tmp("matlab");
            let (h, w) = app.image_shape();
            generate_images(&d.join("input"), 6, h, w, 1).unwrap();
            // Repeat the comparison for stability; report each run.
            for run in 1..=3 {
                let eng = LocalEngine::new(2);
                let r = table1_matlab(
                    &d.join("input"),
                    &d.join(format!("output{run}")),
                    app.clone(),
                    &eng,
                )
                .unwrap();
                println!(
                    "matlab-row run {run}: BLOCK {:>10?}  MIMO {:>10?}  speed-up {:.2}x",
                    r.block.elapsed, r.mimo.elapsed, r.speedup()
                );
            }
        }
        Err(e) => println!("matlab-row skipped: {e}"),
    }
    println!();

    for run in 1..=3 {
        let d = tmp(&format!("java{run}"));
        let eng = LocalEngine::new(3);
        let r = table1_java(&d, Duration::from_millis(5), &eng).unwrap();
        println!(
            "java-row   run {run}: BLOCK {:>10?}  MIMO {:>10?}  speed-up {:.2}x",
            r.block.elapsed, r.mimo.elapsed, r.speedup()
        );
    }
}
