//! `cargo bench --bench table1` — regenerates Table I of the paper.
//!
//! Real end-to-end jobs on the local engine: the image-conversion app
//! (XLA compile = application start-up) over 6 images / 2 tasks, and the
//! word-count app (spin = JVM boot) over 21 files / 3 tasks.  BLOCK vs
//! MIMO speed-up is the reported number; the paper's values are 2.41x
//! (MATLAB) and 2.85x (Java).
//!
//! The trailing SPMD section is the launch-overhead-amortization
//! comparison (per-task vs ganged at N ∈ {1, 4, 16, 64}): virtual-time
//! numbers are written to `BENCH_spmd.json` at the repo root, with a
//! measured wall-clock sweep printed alongside.

use std::time::Duration;

use llmapreduce::apps::CostHint;
use llmapreduce::bench::experiments::{
    spmd_amortization_measured, spmd_amortization_virtual,
    spmd_bench_json, table1_java, table1_matlab,
};
use llmapreduce::prelude::*;
use llmapreduce::workload::images::generate_images;

fn tmp(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir()
        .join(format!("llmr-bench-table1-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn main() {
    println!("TABLE I — speed up with toy examples (paper: 2.41x / 2.85x)\n");

    match Manifest::discover().and_then(|m| ImageConvertApp::new(&m)) {
        Ok(app) => {
            let d = tmp("matlab");
            let (h, w) = app.image_shape();
            generate_images(&d.join("input"), 6, h, w, 1).unwrap();
            // Repeat the comparison for stability; report each run.
            for run in 1..=3 {
                let eng = LocalEngine::new(2);
                let r = table1_matlab(
                    &d.join("input"),
                    &d.join(format!("output{run}")),
                    app.clone(),
                    &eng,
                )
                .unwrap();
                println!(
                    "matlab-row run {run}: BLOCK {:>10?}  MIMO {:>10?}  speed-up {:.2}x",
                    r.block.elapsed, r.mimo.elapsed, r.speedup()
                );
            }
        }
        Err(e) => println!("matlab-row skipped: {e}"),
    }
    println!();

    for run in 1..=3 {
        let d = tmp(&format!("java{run}"));
        let eng = LocalEngine::new(3);
        let r = table1_java(&d, Duration::from_millis(5), &eng).unwrap();
        println!(
            "java-row   run {run}: BLOCK {:>10?}  MIMO {:>10?}  speed-up {:.2}x",
            r.block.elapsed, r.mimo.elapsed, r.speedup()
        );
    }

    println!("\nSPMD — launch-overhead amortization, per-task vs ganged\n");
    let gangs = [1usize, 4, 16, 64];
    // Fixed virtual costs keep the committed artifact byte-reproducible.
    let hint = CostHint {
        startup: Duration::from_millis(128),
        per_item: Duration::from_millis(10),
    };
    let virt = spmd_amortization_virtual(64, hint, &gangs).unwrap();
    let measured = spmd_amortization_measured(
        &tmp("spmd"),
        Duration::from_millis(5),
        &gangs,
    )
    .unwrap();
    for (v, m) in virt.iter().zip(&measured) {
        println!(
            "{:>8}  N={:<3} launches={:<3} per-item overhead: \
             virtual {:>9?}  measured {:>9?}",
            v.mode,
            v.items_per_task,
            v.launches,
            v.per_item_launch_overhead,
            m.per_item_launch_overhead
        );
    }
    assert!(
        virt.windows(2).all(|w| {
            w[1].per_item_launch_overhead < w[0].per_item_launch_overhead
        }),
        "per-item launch overhead must fall as the gang grows"
    );
    let doc = spmd_bench_json("sim-virtual", 64, hint, &virt);
    let path = bench_output_path("BENCH_spmd.json");
    std::fs::write(&path, doc.to_string_pretty()).unwrap();
    println!("\nBENCH_spmd.json -> {}", path.display());
}

/// Write the artifact at the repo root when running inside the checkout
/// (ROADMAP.md marks it); fall back to the current directory.
fn bench_output_path(name: &str) -> std::path::PathBuf {
    let cwd = std::env::current_dir()
        .unwrap_or_else(|_| std::path::PathBuf::from("."));
    for dir in cwd.ancestors() {
        if dir.join("ROADMAP.md").is_file() {
            return dir.join(name);
        }
    }
    cwd.join(name)
}
