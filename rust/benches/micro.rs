//! `cargo bench --bench micro` — L3 hot-path micro-benchmarks.
//!
//! The coordinator paths that run per-job (planner, distribution, script
//! generation, input scanning) and per-simulated-task (DES event loop),
//! the crash-journal append (fsync'd vs buffered) plus the end-to-end
//! submit→complete latency with the journal on and off, and the runtime
//! compile/execute split that *is* the paper's startup-vs-compute
//! mechanism.  The §Perf pass in EXPERIMENTS.md tracks these numbers,
//! and every row is emitted machine-readably to `BENCH_micro.json` at
//! the repo root (schema: `bench::experiments::micro_bench_json`).

use std::time::Duration;

use llmapreduce::bench::experiments::micro_bench_json;
use llmapreduce::bench::{artifact_path, bench_fn, BenchStats};
use llmapreduce::mapreduce::planner::plan;
use llmapreduce::mapreduce::distribution::distribute;
use llmapreduce::options::{Distribution, Options, SchedulerKind};
use llmapreduce::prelude::*;
use llmapreduce::scheduler::dialect::dialect_for;
use llmapreduce::scheduler::journal::{Journal, Record, Replay};
use llmapreduce::scheduler::remote::protocol::Message;
use llmapreduce::scheduler::{JobSpec, TaskSpec, TaskTiming, TaskWork};
use llmapreduce::telemetry::{chrome_trace, Trace};
use llmapreduce::util::json::Json;
use llmapreduce::workdir::scan::InputFile;
use llmapreduce::workload::text::generate_corpus;

fn fake_files(n: usize) -> Vec<InputFile> {
    (0..n)
        .map(|i| InputFile {
            path: format!("/data/in/file_{i:06}.dat").into(),
            relative: format!("file_{i:06}.dat").into(),
        })
        .collect()
}

fn print(s: &BenchStats, items: usize, unit: &str) {
    println!(
        "{}  [{:.0} {unit}/s]",
        s.summary(),
        s.throughput(items)
    );
}

fn main() {
    println!("L3 micro-benchmarks\n");
    let mut all: Vec<BenchStats> = Vec::new();

    // Distribution: the paper's Table II size.
    let s = bench_fn("distribute/block/43580x256", 3, 30, || {
        std::hint::black_box(distribute(43_580, 256, Distribution::Block));
    });
    print(&s, 43_580, "files");
    all.push(s);
    let s = bench_fn("distribute/cyclic/43580x256", 3, 30, || {
        std::hint::black_box(distribute(43_580, 256, Distribution::Cyclic));
    });
    print(&s, 43_580, "files");
    all.push(s);

    // Full planning (naming + assignment) at Table II scale.
    let files = fake_files(43_580);
    let opts = Options::new("/data/in", "/data/out", "mapper").np(256);
    let dialect = dialect_for(SchedulerKind::GridEngine);
    let s = bench_fn("plan/43580x256", 3, 20, || {
        std::hint::black_box(plan(&files, &opts, dialect.as_ref()).unwrap());
    });
    print(&s, 43_580, "files");
    all.push(s);

    // Submission-script generation per dialect.
    for kind in [
        SchedulerKind::GridEngine,
        SchedulerKind::Slurm,
        SchedulerKind::Lsf,
    ] {
        let d = dialect_for(kind);
        let extra: Vec<String> = vec![];
        let req = llmapreduce::scheduler::dialect::SubmitRequest {
            job_name: "mapper",
            tasks: 75_000,
            mapred_dir: ".MAPRED.1",
            exclusive: false,
            depends_on: Some(42),
            extra_options: &extra,
        };
        let s = bench_fn(
            format!("submit-script/{}", kind.as_str()),
            10,
            1000,
            || {
                std::hint::black_box(d.submission_script(&req));
            },
        );
        print(&s, 1, "scripts");
        all.push(s);
    }

    // DES engine: events/second at Fig 18's biggest cell (512 tasks).
    let s = bench_fn("sim/512-tasks-np256", 2, 20, || {
        let eng = SimEngine::new(ClusterConfig::with_width(256));
        let tasks: Vec<TaskSpec> = (0..512)
            .map(|i| TaskSpec {
                task_id: i + 1,
                work: TaskWork::Synthetic {
                    startup: Duration::from_millis(100),
                    per_item: Duration::from_millis(10),
                    items: 1,
                    launches: 1,
                },
            })
            .collect();
        std::hint::black_box(eng.run(JobSpec::new("bench", tasks)).unwrap());
    });
    print(&s, 512, "tasks");
    all.push(s);

    // Table II trace through the sim: 256 tasks, 43,580 virtual files.
    let s = bench_fn("sim/table2-trace", 2, 20, || {
        let params = llmapreduce::workload::trace::TraceParams::table2();
        let eng = SimEngine::new(ClusterConfig::with_width(256));
        std::hint::black_box(
            eng.run(JobSpec::new(
                "trace",
                params.tasks(llmapreduce::options::AppType::Mimo),
            ))
            .unwrap(),
        );
    });
    print(&s, 43_580, "virtual files");
    all.push(s);

    // JSON parser on a manifest-shaped document.
    let doc = r#"{"format":"hlo-text","entries":{"m":{"file":"m.hlo.txt",
        "inputs":[{"shape":[128,128],"dtype":"float32"},
                  {"shape":[128,128],"dtype":"float32"}]}}}"#;
    let s = bench_fn("json/parse-manifest", 10, 2000, || {
        std::hint::black_box(Json::parse(doc).unwrap());
    });
    print(&s, doc.len(), "bytes");
    all.push(s);

    // Crash journal: the fsync'd append every task transition pays,
    // against the buffered (no-fsync) write — the durability tax in
    // isolation.
    let jdir = std::env::temp_dir()
        .join(format!("llmr-bench-journal-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&jdir);
    std::fs::create_dir_all(&jdir).unwrap();
    let rec = Record::TaskDone {
        job: 1,
        idx: 0,
        task_id: 1,
        retries: 0,
        dead_lettered: false,
        timing: None,
    };
    let fsynced = Journal::create(jdir.join("fsync.jsonl")).unwrap();
    let s = bench_fn("journal/record-fsync", 10, 200, || {
        fsynced.record(std::hint::black_box(&rec));
    });
    print(&s, 1, "records");
    all.push(s);
    let buffered =
        Journal::create(jdir.join("buffered.jsonl")).unwrap().no_fsync();
    let s = bench_fn("journal/record-no-fsync", 10, 200, || {
        buffered.record(std::hint::black_box(&rec));
    });
    print(&s, 1, "records");
    all.push(s);

    // And end-to-end: submit→complete latency of a real (small)
    // wordcount pipeline with the journal on vs off.  The delta is the
    // whole-job cost of crash safety, not just the per-append fsync.
    let input = jdir.join("input");
    let _ = generate_corpus(&input, 6, 500, 100, 11).unwrap();
    let engine = LocalEngine::new(2);
    let apps = Apps {
        mapper: llmapreduce::apps::registry::resolve_mapper("wordcount")
            .unwrap(),
        reducer: None,
    };
    for (name, journal_on) in
        [("pipeline/journal-fsync", true), ("pipeline/no-journal", false)]
    {
        let s = bench_fn(name, 1, 5, || {
            let opts = Options::new(&input, jdir.join("out"), "wordcount")
                .np(2)
                .pid(86000)
                .journal(journal_on)
                .workdir(&jdir);
            std::hint::black_box(run(&opts, &apps, &engine).unwrap());
        });
        print(&s, 6, "files");
        all.push(s);
    }

    // Telemetry: the per-observation registry cost and one bus emit
    // fanned out to a live Collector — the price each task transition
    // pays while something is watching.
    let registry = Registry::new();
    let s = bench_fn("telemetry/histogram-record", 10, 2000, || {
        registry.observe(
            "llmr_task_compute_seconds",
            &[("worker", "w1")],
            std::hint::black_box(0.0125),
        );
    });
    print(&s, 1, "observations");
    all.push(s);
    let bus = EventBus::new();
    bus.subscribe(std::sync::Arc::new(Collector::new()));
    let s = bench_fn("telemetry/event-fanout", 10, 2000, || {
        bus.emit(std::hint::black_box(Event::TaskRetry {
            job: 1,
            task_id: 1,
            attempt: 1,
        }));
    });
    print(&s, 1, "events");
    all.push(s);

    // Whole-pipeline telemetry overhead: the same wordcount run with
    // the default-on event bus + status.json writer vs --telemetry=false
    // (journal off in both so the fsync tax does not mask the delta).
    for (name, telemetry_on) in
        [("pipeline/telemetry-on", true), ("pipeline/telemetry-off", false)]
    {
        let s = bench_fn(name, 1, 5, || {
            let opts = Options::new(&input, jdir.join("out"), "wordcount")
                .np(2)
                .pid(86001)
                .journal(false)
                .telemetry(telemetry_on)
                .workdir(&jdir);
            std::hint::black_box(run(&opts, &apps, &engine).unwrap());
        });
        print(&s, 6, "files");
        all.push(s);
    }

    // Tracing: assemble a 256-task trace from a journal replay, then
    // export it as Chrome trace-event text — the whole offline cost of
    // `llmapreduce trace` minus the file I/O (DESIGN.md §12).
    let mut replay = Replay::default();
    replay.apply(Record::JobSubmitted {
        job: 1,
        name: "bench".into(),
        ntasks: 256,
        task_ids: (1..=256).collect(),
    });
    for i in 0..256usize {
        replay.apply(Record::TaskDone {
            job: 1,
            idx: i,
            task_id: i + 1,
            retries: 0,
            dead_lettered: false,
            timing: Some(TaskTiming {
                started_us: (i as u64 % 8) * 10_000,
                finished_us: (i as u64 % 8) * 10_000 + 120_000,
                dispatch_us: 300,
                startup_us: 30_000,
                compute_us: 85_000,
                shipped_us: 4_000,
                ship_out_us: Some(1_800),
                items: 1,
                worker: Some(format!("w{}", i % 4)),
            }),
        });
    }
    let s = bench_fn("trace/assemble-256-tasks", 5, 500, || {
        std::hint::black_box(Trace::from_replay(&replay));
    });
    print(&s, 256, "tasks");
    all.push(s);
    let trace = Trace::from_replay(&replay);
    let s = bench_fn("trace/chrome-export-256-tasks", 5, 200, || {
        std::hint::black_box(chrome_trace(&trace).to_string_compact());
    });
    print(&s, 256, "tasks");
    all.push(s);

    // Whole-pipeline tracing overhead: journal on in both (the spans
    // ride its done records), telemetry off so the bus does not mask
    // the delta — trace on vs off is the span-persistence tax.
    for (name, trace_on) in
        [("pipeline/trace-on", true), ("pipeline/trace-off", false)]
    {
        let s = bench_fn(name, 1, 5, || {
            let opts = Options::new(&input, jdir.join("out"), "wordcount")
                .np(2)
                .pid(86002)
                .telemetry(false)
                .trace(trace_on)
                .workdir(&jdir);
            std::hint::black_box(run(&opts, &apps, &engine).unwrap());
        });
        print(&s, 6, "files");
        all.push(s);
    }

    // Wire codec: the per-frame cost the remote dispatch hot path pays
    // for every assignment — single-task frames vs a 64-task batch, in
    // both framings.  Batch rows count 64 tasks, so the tasks/s column
    // shows the amortization directly (DESIGN.md §13).
    let assign = |i: usize| llmapreduce::scheduler::remote::protocol::TaskAssign {
        job: 7,
        task_idx: i,
        task_id: i + 1,
        work: llmapreduce::scheduler::remote::protocol::WireWork::Synthetic {
            startup_us: 1_000,
            per_item_us: 250,
            items: 4,
            launches: 1,
        },
    };
    let single = {
        let a = assign(0);
        Message::Assign {
            job: a.job,
            task_idx: a.task_idx,
            task_id: a.task_id,
            work: a.work,
        }
    };
    let batch = Message::AssignBatch {
        tasks: (0..64).map(assign).collect(),
    };
    for (label, msg, tasks) in
        [("single", &single, 1usize), ("batch64", &batch, 64)]
    {
        let line = msg.encode();
        let bytes = msg.encode_binary();
        let s = bench_fn(format!("wire/json-encode-{label}"), 10, 2000, || {
            std::hint::black_box(msg.encode());
        });
        print(&s, tasks, "tasks");
        all.push(s);
        let s = bench_fn(format!("wire/json-decode-{label}"), 10, 2000, || {
            std::hint::black_box(Message::decode(&line).unwrap());
        });
        print(&s, tasks, "tasks");
        all.push(s);
        let s = bench_fn(format!("wire/bin-encode-{label}"), 10, 2000, || {
            std::hint::black_box(msg.encode_binary());
        });
        print(&s, tasks, "tasks");
        all.push(s);
        let s = bench_fn(format!("wire/bin-decode-{label}"), 10, 2000, || {
            std::hint::black_box(Message::decode_binary(&bytes).unwrap());
        });
        print(&s, tasks, "tasks");
        all.push(s);
    }

    // Runtime: compile (startup) vs execute (per-file) — the mechanism.
    if let Ok(manifest) = Manifest::discover() {
        let entry = manifest.entry("matmul_pair").unwrap().clone();
        let compile = bench_fn("xla/compile-matmul_pair", 1, 10, || {
            std::hint::black_box(
                llmapreduce::runtime::XlaExecutable::from_entry(&entry)
                    .unwrap(),
            );
        });
        print(&compile, 1, "compiles");

        let exe =
            llmapreduce::runtime::XlaExecutable::from_entry(&entry).unwrap();
        let n = entry.inputs[0].shape[0];
        let a = vec![0.5f32; n * n];
        let b = vec![0.25f32; n * n];
        let execute = bench_fn("xla/execute-matmul_pair", 3, 50, || {
            std::hint::black_box(exe.run_f32(&[&a, &b]).unwrap());
        });
        print(&execute, 2 * n * n * n, "flops");
        println!(
            "\nstartup:execute ratio = {:.1} (the amortization MIMO exploits)",
            compile.median.as_secs_f64() / execute.median.as_secs_f64()
        );
        all.push(compile);
        all.push(execute);
    } else {
        println!("(xla benches skipped: no artifacts)");
    }

    let doc = micro_bench_json("cargo-bench-micro", &all);
    let path = artifact_path("BENCH_micro.json");
    std::fs::write(&path, doc.to_string_pretty()).unwrap();
    println!("\njson: {}", path.display());
    let _ = std::fs::remove_dir_all(&jdir);
}
