//! `cargo bench --bench micro` — L3 hot-path micro-benchmarks.
//!
//! The coordinator paths that run per-job (planner, distribution, script
//! generation, input scanning) and per-simulated-task (DES event loop),
//! plus the runtime compile/execute split that *is* the paper's
//! startup-vs-compute mechanism.  The §Perf pass in EXPERIMENTS.md tracks
//! these numbers.

use std::time::Duration;

use llmapreduce::bench::{bench_fn, BenchStats};
use llmapreduce::mapreduce::planner::plan;
use llmapreduce::mapreduce::distribution::distribute;
use llmapreduce::options::{Distribution, Options, SchedulerKind};
use llmapreduce::prelude::*;
use llmapreduce::scheduler::dialect::dialect_for;
use llmapreduce::scheduler::{JobSpec, TaskSpec, TaskWork};
use llmapreduce::util::json::Json;
use llmapreduce::workdir::scan::InputFile;

fn fake_files(n: usize) -> Vec<InputFile> {
    (0..n)
        .map(|i| InputFile {
            path: format!("/data/in/file_{i:06}.dat").into(),
            relative: format!("file_{i:06}.dat").into(),
        })
        .collect()
}

fn print(s: &BenchStats, items: usize, unit: &str) {
    println!(
        "{}  [{:.0} {unit}/s]",
        s.summary(),
        s.throughput(items)
    );
}

fn main() {
    println!("L3 micro-benchmarks\n");

    // Distribution: the paper's Table II size.
    let s = bench_fn("distribute/block/43580x256", 3, 30, || {
        std::hint::black_box(distribute(43_580, 256, Distribution::Block));
    });
    print(&s, 43_580, "files");
    let s = bench_fn("distribute/cyclic/43580x256", 3, 30, || {
        std::hint::black_box(distribute(43_580, 256, Distribution::Cyclic));
    });
    print(&s, 43_580, "files");

    // Full planning (naming + assignment) at Table II scale.
    let files = fake_files(43_580);
    let opts = Options::new("/data/in", "/data/out", "mapper").np(256);
    let dialect = dialect_for(SchedulerKind::GridEngine);
    let s = bench_fn("plan/43580x256", 3, 20, || {
        std::hint::black_box(plan(&files, &opts, dialect.as_ref()).unwrap());
    });
    print(&s, 43_580, "files");

    // Submission-script generation per dialect.
    for kind in [
        SchedulerKind::GridEngine,
        SchedulerKind::Slurm,
        SchedulerKind::Lsf,
    ] {
        let d = dialect_for(kind);
        let extra: Vec<String> = vec![];
        let req = llmapreduce::scheduler::dialect::SubmitRequest {
            job_name: "mapper",
            tasks: 75_000,
            mapred_dir: ".MAPRED.1",
            exclusive: false,
            depends_on: Some(42),
            extra_options: &extra,
        };
        let s = bench_fn(
            format!("submit-script/{}", kind.as_str()),
            10,
            1000,
            || {
                std::hint::black_box(d.submission_script(&req));
            },
        );
        print(&s, 1, "scripts");
    }

    // DES engine: events/second at Fig 18's biggest cell (512 tasks).
    let s = bench_fn("sim/512-tasks-np256", 2, 20, || {
        let eng = SimEngine::new(ClusterConfig::with_width(256));
        let tasks: Vec<TaskSpec> = (0..512)
            .map(|i| TaskSpec {
                task_id: i + 1,
                work: TaskWork::Synthetic {
                    startup: Duration::from_millis(100),
                    per_item: Duration::from_millis(10),
                    items: 1,
                    launches: 1,
                },
            })
            .collect();
        std::hint::black_box(eng.run(JobSpec::new("bench", tasks)).unwrap());
    });
    print(&s, 512, "tasks");

    // Table II trace through the sim: 256 tasks, 43,580 virtual files.
    let s = bench_fn("sim/table2-trace", 2, 20, || {
        let params = llmapreduce::workload::trace::TraceParams::table2();
        let eng = SimEngine::new(ClusterConfig::with_width(256));
        std::hint::black_box(
            eng.run(JobSpec::new(
                "trace",
                params.tasks(llmapreduce::options::AppType::Mimo),
            ))
            .unwrap(),
        );
    });
    print(&s, 43_580, "virtual files");

    // JSON parser on a manifest-shaped document.
    let doc = r#"{"format":"hlo-text","entries":{"m":{"file":"m.hlo.txt",
        "inputs":[{"shape":[128,128],"dtype":"float32"},
                  {"shape":[128,128],"dtype":"float32"}]}}}"#;
    let s = bench_fn("json/parse-manifest", 10, 2000, || {
        std::hint::black_box(Json::parse(doc).unwrap());
    });
    print(&s, doc.len(), "bytes");

    // Runtime: compile (startup) vs execute (per-file) — the mechanism.
    if let Ok(manifest) = Manifest::discover() {
        let entry = manifest.entry("matmul_pair").unwrap().clone();
        let compile = bench_fn("xla/compile-matmul_pair", 1, 10, || {
            std::hint::black_box(
                llmapreduce::runtime::XlaExecutable::from_entry(&entry)
                    .unwrap(),
            );
        });
        print(&compile, 1, "compiles");

        let exe =
            llmapreduce::runtime::XlaExecutable::from_entry(&entry).unwrap();
        let n = entry.inputs[0].shape[0];
        let a = vec![0.5f32; n * n];
        let b = vec![0.25f32; n * n];
        let execute = bench_fn("xla/execute-matmul_pair", 3, 50, || {
            std::hint::black_box(exe.run_f32(&[&a, &b]).unwrap());
        });
        print(&execute, 2 * n * n * n, "flops");
        println!(
            "\nstartup:execute ratio = {:.1} (the amortization MIMO exploits)",
            compile.median.as_secs_f64() / execute.median.as_secs_f64()
        );
    } else {
        println!("(xla benches skipped: no artifacts)");
    }
}
