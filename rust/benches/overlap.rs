//! `cargo bench --bench overlap` — barriered vs overlapped map→reduce.
//!
//! The paper's Fig 1 launcher barriers the reduce job on the *whole* map
//! array job; `--overlap=true` instead releases one partial-reduce task
//! per mapper task the moment that task completes (task-granularity
//! scheduler dependencies, DESIGN.md §4).  This bench runs the same
//! I/O-bound workload both ways on the background-dispatch local engine
//! and prints makespan, utilization and the speed-up the removed barrier
//! buys.
//!
//! The workload models the regime where overlap pays: mapper task costs
//! ramp (time-ordered inputs growing through the day — the same
//! straggler pattern as the block-vs-cyclic ablation), so early slots go
//! idle while the stragglers finish, and the reducer's per-file
//! consumption is substantial.  Tasks sleep rather than spin so the
//! comparison is honest on a single-core container.
//!
//! Expected shape: overlapped makespan clearly below barriered (the
//! partial folds hide inside map-phase idle time and the final merge
//! reads pre-folded partials), utilization correspondingly higher.

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use llmapreduce::apps::{MapApp, MapInstance, ReduceApp};
use llmapreduce::metrics::report::overlap_comparison;
use llmapreduce::prelude::*;

/// Mapper whose per-file cost is the number of milliseconds stored in the
/// input file (I/O-bound: sleeps, does not spin).
struct SleepMapApp;

struct SleepMapInstance;

impl MapApp for SleepMapApp {
    fn name(&self) -> &str {
        "sleep-map"
    }

    fn startup(&self) -> Result<Box<dyn MapInstance>> {
        Ok(Box::new(SleepMapInstance))
    }
}

impl MapInstance for SleepMapInstance {
    fn process(&mut self, input: &Path, output: &Path) -> Result<()> {
        let ms: u64 = fs::read_to_string(input)
            .map_err(|e| Error::io(input.to_path_buf(), e))?
            .trim()
            .parse()
            .unwrap_or(0);
        std::thread::sleep(Duration::from_millis(ms));
        fs::write(output, "mapped\n")
            .map_err(|e| Error::io(output.to_path_buf(), e))
    }
}

/// Reducer that pays `consume_ms` per consumed file — in one big scan at
/// the barrier, or spread across eager partial folds.
struct SleepReducer {
    consume_ms: u64,
}

impl SleepReducer {
    fn concat(&self, files: &[PathBuf], out: &Path) -> Result<()> {
        std::thread::sleep(Duration::from_millis(
            self.consume_ms * files.len() as u64,
        ));
        let mut merged = String::new();
        for f in files {
            merged.push_str(
                &fs::read_to_string(f)
                    .map_err(|e| Error::io(f.clone(), e))?,
            );
        }
        fs::write(out, merged).map_err(|e| Error::io(out.to_path_buf(), e))
    }
}

impl ReduceApp for SleepReducer {
    fn name(&self) -> &str {
        "sleep-reduce"
    }

    fn reduce(&self, dir: &Path, out: &Path) -> Result<()> {
        let mut files: Vec<PathBuf> = fs::read_dir(dir)
            .map_err(|e| Error::io(dir.to_path_buf(), e))?
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| p.is_file() && *p != *out)
            .collect();
        files.sort();
        self.concat(&files, out)
    }

    fn reduce_partial(&self, files: &[PathBuf], out: &Path) -> Result<()> {
        self.concat(files, out)
    }

    fn supports_partial(&self) -> bool {
        true
    }
}

fn run_mode(
    root: &Path,
    input: &Path,
    overlap: bool,
) -> Result<MapReduceReport> {
    let output = root.join(if overlap { "out-overlap" } else { "out-barrier" });
    let opts = Options::new(input, &output, "sleep-map")
        .np(8)
        .reducer("sleep-reduce")
        .overlap(overlap)
        .pid(if overlap { 82002 } else { 82001 })
        .workdir(root);
    let apps = Apps {
        mapper: Arc::new(SleepMapApp),
        reducer: Some(Arc::new(SleepReducer { consume_ms: 10 })),
    };
    let engine = LocalEngine::new(4);
    run(&opts, &apps, &engine)
}

fn main() -> Result<()> {
    let root = std::env::temp_dir()
        .join(format!("llmr-bench-overlap-{}", std::process::id()));
    let _ = fs::remove_dir_all(&root);
    let input = root.join("input");
    fs::create_dir_all(&input)
        .map_err(|e| Error::io(input.clone(), e))?;
    // 16 files whose costs ramp 0..75ms (block tasks become stragglers).
    for k in 0..16u64 {
        let f = input.join(format!("f{k:02}.txt"));
        fs::write(&f, format!("{}\n", 5 * k))
            .map_err(|e| Error::io(f.clone(), e))?;
    }

    println!("== overlapped map->reduce vs Fig 1 barrier ==");
    println!(
        "16 ramped inputs (0..75ms), np=8, slots=4, reduce 10ms/file\n"
    );
    let barriered = run_mode(&root, &input, false)?;
    let overlapped = run_mode(&root, &input, true)?;
    println!("{}", overlap_comparison(&barriered, &overlapped));
    let speedup = barriered.elapsed().as_secs_f64()
        / overlapped.elapsed().as_secs_f64().max(1e-12);
    println!(
        "barrier removed: {:.2}x ({} -> {})",
        speedup,
        llmapreduce::util::fmt_duration(barriered.elapsed()),
        llmapreduce::util::fmt_duration(overlapped.elapsed()),
    );
    let _ = fs::remove_dir_all(&root);
    Ok(())
}
