//! `cargo bench --bench table2` — regenerates Table II of the paper.
//!
//! The 43,580-file / 256-task real-user-application trace on the
//! calibrated discrete-event simulator (the dataset is the one input we
//! cannot have; DESIGN.md §3 documents the substitution).  The paper
//! reports 11.57x; the trace parameters pin startup:compute at the ratio
//! that regime implies, and the simulator adds dispatch effects.
//!
//! Also sweeps the startup:compute ratio to show where 11.57x sits.

use std::time::Duration;

use llmapreduce::bench::experiments::table2;
use llmapreduce::workload::trace::TraceParams;

fn main() {
    println!("TABLE II — real-world trace (paper: 11.57x)\n");
    let params = TraceParams::table2();
    let r = table2(params).unwrap();
    println!("{}", r.table());
    println!(
        "ideal (no dispatch): {:.2}x   simulated: {:.2}x   paper: 11.57x\n",
        params.ideal_mimo_speedup(),
        r.speedup()
    );

    println!("ablation: startup:per-file ratio vs speed-up (171 files/task)");
    for ratio in [1u64, 2, 5, 10, 11, 20, 50] {
        let p = TraceParams {
            startup: Duration::from_millis(1000 * ratio),
            per_item: Duration::from_millis(1000),
            ..params
        };
        let r = table2(p).unwrap();
        println!("  ratio {ratio:>3}: {:.2}x", r.speedup());
    }
}
