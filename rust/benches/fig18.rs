//! `cargo bench --bench fig18` — regenerates Fig 18: computational
//! overhead per concurrent array task vs np, for DEFAULT / BLOCK / MIMO.
//!
//! Costs are calibrated from the real matmul app when artifacts exist
//! (XLA compile = start-up), else representative constants; the sweep
//! runs on the discrete-event simulator (512 files, np 1..256, the
//! paper's §IV parameters).
//!
//! Expected shape (the paper's findings): DEFAULT ≳ BLOCK falling
//! linearly in np; MIMO flat and far below; all converge at 1 file/task.

use std::time::Duration;

use llmapreduce::apps::CostHint;
use llmapreduce::bench::experiments::{fig18_19_sweep, PAPER_WIDTHS};
use llmapreduce::metrics::report::{overhead_series, sweep_csv};
use llmapreduce::prelude::*;
use llmapreduce::scheduler::cost::Calibration;
use llmapreduce::workload::matrices::generate_matrix_lists;

fn calibrate() -> CostHint {
    let fallback = CostHint {
        startup: Duration::from_millis(30),
        per_item: Duration::from_millis(3),
    };
    let Ok(manifest) = Manifest::discover() else { return fallback };
    let Ok(app) = MatmulChainApp::new(&manifest) else { return fallback };
    let d = std::env::temp_dir()
        .join(format!("llmr-bench-fig18-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    let (l, n) = app.static_shape();
    let paths = generate_matrix_lists(&d, 4, l, n, 3).unwrap();
    let pairs: Vec<_> = paths
        .iter()
        .map(|p| (p.clone(), p.with_extension("out")))
        .collect();
    Calibration::measure(app.as_ref(), &pairs, 3)
        .map(|c| c.hint)
        .unwrap_or(fallback)
}

fn main() {
    let hint = calibrate();
    println!(
        "FIG 18 — overhead per concurrent task (calibrated startup={:?}, per-file={:?})\n",
        hint.startup, hint.per_item
    );
    let sweep =
        fig18_19_sweep(512, &PAPER_WIDTHS, hint, Duration::from_millis(1))
            .unwrap();
    println!("{}", overhead_series(&sweep));

    let csv = std::env::temp_dir().join("llmr-bench-fig18.csv");
    std::fs::write(&csv, sweep_csv(&sweep)).unwrap();
    println!("csv: {}", csv.display());

    // Shape assertions — the bench FAILS if the paper's findings invert.
    let m1 = sweep.get("MIMO", 1).unwrap().overhead_per_task;
    let m256 = sweep.get("MIMO", 256).unwrap().overhead_per_task;
    let b1 = sweep.get("BLOCK", 1).unwrap().overhead_per_task;
    let b256 = sweep.get("BLOCK", 256).unwrap().overhead_per_task;
    let d1 = sweep.get("DEFAULT", 1).unwrap().overhead_per_task;
    assert!(
        m1.as_secs_f64() / m256.as_secs_f64() < 3.0,
        "MIMO overhead must stay ~flat"
    );
    assert!(
        b1.as_secs_f64() / b256.as_secs_f64() > 50.0,
        "BLOCK overhead must fall ~linearly"
    );
    assert!(d1 >= b1, "DEFAULT >= BLOCK at np=1");
    assert!(b1 > m1 * 10, "BLOCK >> MIMO at np=1");
    println!("shape checks: OK (DEFAULT >= BLOCK >> MIMO, MIMO flat)");
}
