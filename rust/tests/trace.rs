//! Tracing acceptance suite (DESIGN.md §12).
//!
//! The bar: `llmapreduce trace` assembles per-task span timelines
//! whose durations agree with the journal the `status` fold reads —
//! on the local *and* remote engines, and after a real SIGKILL +
//! resume.  The exported Chrome trace must be structurally loadable
//! (every phase slice nests inside its task's umbrella slice), and
//! the critical-path report's per-phase totals must sum to within 5%
//! of the measured makespan (the tiling makes them exact).

use std::fs;
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use llmapreduce::mapreduce::{run, Apps};
use llmapreduce::options::Options;
use llmapreduce::prelude::LocalEngine;
use llmapreduce::scheduler::journal::{Replay, JOURNAL_FILE};
use llmapreduce::telemetry::{critical_path, trace_workdir, Trace};
use llmapreduce::util::json::Json;

const BIN: &str = env!("CARGO_BIN_EXE_llmapreduce");

fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("llmr-trace-{tag}-{}", std::process::id()));
    let _ = fs::remove_dir_all(&d);
    fs::create_dir_all(&d).unwrap();
    d
}

fn write_corpus(input: &Path, nfiles: usize) {
    fs::create_dir_all(input).unwrap();
    let vocab = ["alpha", "beta", "gamma", "delta", "epsilon"];
    for i in 0..nfiles {
        let mut text = String::new();
        for (w, word) in vocab.iter().enumerate() {
            for _ in 0..(i + w) % 4 + 1 {
                text.push_str(word);
                text.push(' ');
            }
        }
        fs::write(input.join(format!("doc{i:02}.txt")), text).unwrap();
    }
}

fn wc_apps() -> Apps {
    Apps {
        mapper: llmapreduce::apps::registry::resolve_mapper("wordcount")
            .unwrap(),
        reducer: Some(
            llmapreduce::apps::registry::resolve_reducer(
                "wordcount-reducer",
            )
            .unwrap(),
        ),
    }
}

/// The two acceptance invariants on an assembled trace:
///
/// 1. every task's span durations sum to its journal-recorded
///    `finished_us` (the trace agrees with the `status`/replay fold);
/// 2. the critical path's per-phase totals sum to within 5% of the
///    makespan (exact, by the tiling construction).
fn assert_trace_invariants(trace: &Trace, replay: &Replay) {
    let traced: usize = trace.jobs.values().map(|j| j.tasks.len()).sum();
    let journaled: usize =
        replay.jobs.values().map(|j| j.timings.len()).sum();
    assert_eq!(traced, journaled, "one task trace per journaled timing");
    assert!(traced > 0, "nothing was traced");
    for (id, job) in trace.jobs.iter() {
        let folded = &replay.jobs[id];
        for (task_id, t) in job.tasks.iter() {
            let (retries, timing) = &folded.timings[task_id];
            assert_eq!(t.attempt, *retries);
            assert_eq!(&t.timing, timing, "trace re-reads the journal");
            let span_sum: u64 = t.spans.iter().map(|s| s.dur_us()).sum();
            assert_eq!(
                span_sum,
                t.finished_us(),
                "job {id} task {task_id}: spans must tile the task"
            );
        }
    }
    let path = critical_path(trace).expect("completed tasks exist");
    let sum: u64 = path.phase_totals_us.iter().sum();
    assert_eq!(path.makespan_us, trace.makespan_us());
    assert!(
        sum.abs_diff(path.makespan_us) as f64
            <= 0.05 * path.makespan_us as f64,
        "phase totals {sum}us vs makespan {}us drift past 5%",
        path.makespan_us
    );
}

/// Structural Perfetto-loadability: valid JSON, a `traceEvents` array,
/// and every phase slice nested inside its task's umbrella bounds.
fn assert_chrome_trace_nests(doc: &Json, expected_tasks: usize) {
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("traceEvents array");
    let mut umbrellas = 0usize;
    let mut bounds = std::collections::BTreeMap::new();
    for e in events {
        if e.get("ph").and_then(Json::as_str) != Some("X") {
            continue;
        }
        let pid = e.get("pid").and_then(Json::as_usize).unwrap();
        let tid = e.get("tid").and_then(Json::as_usize).unwrap();
        let ts = e.get("ts").and_then(Json::as_usize).unwrap();
        let dur = e.get("dur").and_then(Json::as_usize).unwrap();
        let name = e.get("name").and_then(Json::as_str).unwrap();
        if name.starts_with("task ") {
            umbrellas += 1;
            bounds.insert((pid, tid), ts + dur);
        } else {
            let end = bounds
                .get(&(pid, tid))
                .expect("umbrella slice precedes its phases");
            assert!(
                ts + dur <= *end,
                "phase '{name}' escapes task ({pid},{tid})"
            );
        }
    }
    assert_eq!(umbrellas, expected_tasks, "one umbrella slice per task");
}

// ---------------------------------------------------------------------------
// Local engine
// ---------------------------------------------------------------------------

#[test]
fn local_engine_trace_agrees_with_the_journal_fold() {
    let root = tmp("local");
    let input = root.join("input");
    write_corpus(&input, 10);
    let eng = LocalEngine::new(2);
    run(
        &Options::new(&input, root.join("out"), "wordcount")
            .np(4)
            .reducer("wordcount-reducer")
            .pid(96001)
            .keep(true)
            .workdir(&root),
        &wc_apps(),
        &eng,
    )
    .unwrap();
    let wd = root.join(".MAPRED.96001");

    let trace = trace_workdir(&wd).unwrap();
    let replay = Replay::load(&wd.join(JOURNAL_FILE)).unwrap();
    assert_trace_invariants(&trace, &replay);
    let traced: usize = trace.jobs.values().map(|j| j.tasks.len()).sum();
    assert_eq!(traced, 5, "4 map tasks + 1 reduce task");

    // The subcommand: report on stdout, Chrome export in the workdir.
    let out = Command::new(BIN)
        .args(["trace".to_string(), wd.display().to_string()])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "trace failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    for section in
        ["critical path", "per-phase totals", "stragglers", "wrote"]
    {
        assert!(text.contains(section), "missing '{section}': {text}");
    }
    let doc = Json::parse(
        &fs::read_to_string(wd.join("trace.json")).unwrap(),
    )
    .unwrap();
    assert_chrome_trace_nests(&doc, traced);

    // The raw-JSON format round-trips the assembled structure.
    let raw = root.join("raw.json");
    let out = Command::new(BIN)
        .args([
            "trace".to_string(),
            wd.display().to_string(),
            "--format=json".to_string(),
            format!("--out={}", raw.display()),
        ])
        .output()
        .unwrap();
    assert!(out.status.success());
    let doc = Json::parse(&fs::read_to_string(&raw).unwrap()).unwrap();
    assert_eq!(
        doc.get("makespan_us").and_then(Json::as_usize),
        Some(trace.makespan_us() as usize)
    );
}

#[test]
fn trace_on_a_journalless_workdir_fails_with_one_line() {
    let root = tmp("nojournal");
    let out = Command::new(BIN)
        .args(["trace".to_string(), root.display().to_string()])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    let lines: Vec<&str> =
        stderr.lines().filter(|l| !l.trim().is_empty()).collect();
    assert_eq!(lines.len(), 1, "one-line error, got: {stderr}");
    assert!(lines[0].contains("tracing needs a journaled run"));
}

#[test]
fn trace_off_runs_leave_nothing_to_trace() {
    let root = tmp("off");
    let input = root.join("input");
    write_corpus(&input, 6);
    let eng = LocalEngine::new(2);
    run(
        &Options::new(&input, root.join("out"), "wordcount")
            .np(2)
            .pid(96002)
            .trace(false)
            .keep(true)
            .workdir(&root),
        &wc_apps(),
        &eng,
    )
    .unwrap();
    let wd = root.join(".MAPRED.96002");
    assert!(wd.join(JOURNAL_FILE).is_file(), "journal unaffected");
    let err = trace_workdir(&wd).unwrap_err();
    assert!(
        format!("{err}").contains("no span timings"),
        "got: {err}"
    );
}

// ---------------------------------------------------------------------------
// Remote engine, SIGKILL mid-job, resume, then trace offline
// ---------------------------------------------------------------------------

fn wait_for_workdir(base: &Path, limit: Duration) -> PathBuf {
    let start = Instant::now();
    loop {
        if let Ok(entries) = fs::read_dir(base) {
            for e in entries.flatten() {
                let name = e.file_name().to_string_lossy().to_string();
                if name.starts_with(".MAPRED.") {
                    return e.path();
                }
            }
        }
        assert!(
            start.elapsed() < limit,
            "no .MAPRED.* workdir appeared under {}",
            base.display()
        );
        std::thread::sleep(Duration::from_millis(20));
    }
}

fn wait_for_first_done(wd: &Path, limit: Duration) {
    let start = Instant::now();
    let path = wd.join(JOURNAL_FILE);
    loop {
        if let Ok(text) = fs::read_to_string(&path) {
            if text.contains("\"rec\":\"done\"") {
                return;
            }
        }
        assert!(
            start.elapsed() < limit,
            "no task completed within {limit:?} ({})",
            path.display()
        );
        std::thread::sleep(Duration::from_millis(20));
    }
}

fn wait_for_listener(port: u16, limit: Duration) {
    let start = Instant::now();
    let addr = format!("127.0.0.1:{port}");
    loop {
        if TcpStream::connect(&addr).is_ok() {
            return;
        }
        assert!(
            start.elapsed() < limit,
            "no listener on {addr} within {limit:?}"
        );
        std::thread::sleep(Duration::from_millis(25));
    }
}

fn wait_exit(child: &mut Child, what: &str, limit: Duration) {
    let start = Instant::now();
    loop {
        match child.try_wait().unwrap() {
            Some(st) => {
                assert!(st.success(), "{what} exited with {st}");
                return;
            }
            None if start.elapsed() > limit => {
                let _ = child.kill();
                panic!("{what} did not finish within {limit:?}");
            }
            None => std::thread::sleep(Duration::from_millis(25)),
        }
    }
}

fn spawn_worker(port: u16, name: &str) -> Child {
    Command::new(BIN)
        .args([
            "worker".to_string(),
            format!("--connect=127.0.0.1:{port}"),
            "--slots=2".to_string(),
            format!("--name={name}"),
        ])
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .unwrap()
}

#[test]
fn sigkilled_remote_job_traces_after_resume() {
    let root = tmp("sigkill-remote");
    let input = root.join("input");
    write_corpus(&input, 8);
    let slow = root.join("slow-map.sh");
    fs::write(
        &slow,
        "#!/bin/sh\nsleep 0.3\ntr 'a-z' 'A-Z' < \"$1\" > \"$2\"\n",
    )
    .unwrap();
    let mapper = format!("sh {}", slow.display());
    // Two ports per test process, clear of the ephemeral range (the
    // resume.rs tests offset by +0/+1 from the same base; stay clear).
    let port1 = 21000 + ((std::process::id() + 7) % 39000) as u16;
    let port2 = port1 + 1;

    let crash_base = root.join("crash");
    fs::create_dir_all(&crash_base).unwrap();
    let mut coord = Command::new(BIN)
        .current_dir(&root)
        .args([
            "run".to_string(),
            format!("--input={}", input.display()),
            format!("--output={}", root.join("out").display()),
            format!("--mapper={mapper}"),
            "--np=8".to_string(),
            "--keep=true".to_string(),
            format!("--workdir={}", crash_base.display()),
            "--engine=remote".to_string(),
            format!("--listen=127.0.0.1:{port1}"),
            "--min-workers=1".to_string(),
        ])
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .unwrap();
    wait_for_listener(port1, Duration::from_secs(60));
    let mut worker1 = spawn_worker(port1, "w1");
    let wd = wait_for_workdir(&crash_base, Duration::from_secs(60));
    wait_for_first_done(&wd, Duration::from_secs(120));
    coord.kill().unwrap(); // SIGKILL: no final flush, no cleanup
    let _ = coord.wait();
    let _ = worker1.kill(); // the fleet dies with its coordinator
    let _ = worker1.wait();

    // The torn journal already traces: the tasks that completed before
    // the kill carry their span timings.
    let partial = trace_workdir(&wd).unwrap();
    let partial_replay = Replay::load(&wd.join(JOURNAL_FILE)).unwrap();
    assert_trace_invariants(&partial, &partial_replay);

    // Resume on a fresh port with a fresh worker, then trace the
    // merged journal offline.
    let mut res = Command::new(BIN)
        .current_dir(&root)
        .args([
            "resume".to_string(),
            wd.display().to_string(),
            "--engine=remote".to_string(),
            format!("--listen=127.0.0.1:{port2}"),
            "--min-workers=1".to_string(),
        ])
        .stdout(Stdio::null())
        .stderr(Stdio::inherit())
        .spawn()
        .unwrap();
    wait_for_listener(port2, Duration::from_secs(60));
    let mut worker2 = spawn_worker(port2, "w2");
    wait_exit(&mut res, "remote resume", Duration::from_secs(120));
    let _ = worker2.kill();
    let _ = worker2.wait();

    let trace = trace_workdir(&wd).unwrap();
    let replay = Replay::load(&wd.join(JOURNAL_FILE)).unwrap();
    assert!(trace.resumes >= 1, "the resume marker is folded in");
    assert_trace_invariants(&trace, &replay);
    // Every one of the 8 map tasks is traced: pre-kill completions from
    // the first coordinator's records, the rest from the resumed run.
    let map_job = trace
        .jobs
        .values()
        .find(|j| j.ntasks == 8)
        .expect("map job traced");
    assert_eq!(map_job.tasks.len(), 8, "all map tasks carry spans");
    // Remote tasks are worker-attributed in their spans' source timing.
    assert!(
        map_job
            .tasks
            .values()
            .all(|t| t.timing.worker.is_some()),
        "remote task timings carry worker attribution"
    );

    let out = Command::new(BIN)
        .args(["trace".to_string(), wd.display().to_string()])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "trace failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("resumed"), "report notes the resume: {text}");
    let doc = Json::parse(
        &fs::read_to_string(wd.join("trace.json")).unwrap(),
    )
    .unwrap();
    let traced: usize = trace.jobs.values().map(|j| j.tasks.len()).sum();
    assert_chrome_trace_nests(&doc, traced);
}
