//! SPMD ganging equivalence suite (DESIGN.md §7).
//!
//! The morph under test: `--items-per-task=N` packs item batches into
//! long-running tasks executed by one persistent app instance each.
//! The acceptance bar is *byte identity* — the merged wordcount output
//! of a ganged run must equal the per-task run bit-for-bit on every
//! engine (local, sim-exec, remote), through `--overlap` and nested
//! multi-level fan-out — plus chaos coverage: losing a worker mid-batch
//! re-runs only that worker's batch, and injected-failure retries
//! replay identically across engines under a shared [`FailurePolicy`]
//! seed.

use std::fs;
use std::path::{Path, PathBuf};
use std::thread::JoinHandle;
use std::time::Duration;

use llmapreduce::apps::CostHint;
use llmapreduce::bench::experiments::{
    spmd_amortization_virtual, spmd_bench_json,
};
use llmapreduce::error::Result;
use llmapreduce::mapreduce::multilevel::run_nested;
use llmapreduce::mapreduce::{run, Apps, MapReduceReport};
use llmapreduce::options::Options;
use llmapreduce::prelude::{
    run_worker, CoordinatorConfig, FailurePolicy, LocalEngine,
    RemoteCoordinator, WorkerConfig,
};
use llmapreduce::scheduler::sim::{ClusterConfig, SimEngine};
use llmapreduce::util::json::Json;

fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("llmr-spmd-{tag}-{}", std::process::id()));
    let _ = fs::remove_dir_all(&d);
    fs::create_dir_all(&d).unwrap();
    d
}

/// Deterministic corpus: overlapping word multisets across files.
fn write_corpus(input: &Path, nfiles: usize) {
    fs::create_dir_all(input).unwrap();
    let vocab = ["alpha", "beta", "gamma", "delta", "epsilon"];
    for i in 0..nfiles {
        let mut text = String::new();
        for (w, word) in vocab.iter().enumerate() {
            for _ in 0..(i + w) % 4 + 1 {
                text.push_str(word);
                text.push(' ');
            }
        }
        fs::write(input.join(format!("doc{i:02}.txt")), text).unwrap();
    }
}

fn wc_opts(input: &Path, output: PathBuf, pid: u32) -> Options {
    Options::new(input, output, "wordcount")
        .np(4)
        .reducer("wordcount-reducer")
        .pid(pid)
}

fn wc_apps() -> Apps {
    Apps {
        mapper: llmapreduce::apps::registry::resolve_mapper("wordcount")
            .unwrap(),
        reducer: Some(
            llmapreduce::apps::registry::resolve_reducer(
                "wordcount-reducer",
            )
            .unwrap(),
        ),
    }
}

fn redout(report: &MapReduceReport) -> Vec<u8> {
    fs::read(report.redout_path.as_ref().expect("reduced")).unwrap()
}

fn spawn_workers(
    coordinator: &RemoteCoordinator,
    n: usize,
) -> Vec<JoinHandle<Result<()>>> {
    let addr = coordinator.local_addr().to_string();
    (0..n)
        .map(|i| {
            let config = WorkerConfig::new(addr.clone())
                .name(format!("w{i}"))
                .slots(1);
            std::thread::spawn(move || run_worker(config))
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Byte identity: per-task vs ganged, across engines
// ---------------------------------------------------------------------------

#[test]
fn ganged_wordcount_byte_identical_on_local_engine() {
    let root = tmp("local");
    let input = root.join("input");
    write_corpus(&input, 10);

    let eng = LocalEngine::new(2);
    let baseline = run(
        &wc_opts(&input, root.join("out-base"), 93001).workdir(&root),
        &wc_apps(),
        &eng,
    )
    .unwrap();
    let base_bytes = redout(&baseline);
    assert!(!base_bytes.is_empty());

    // Gang sizes covering N=1, an uneven tail, and N > items.
    for (i, n) in [1usize, 3, 5, 64].into_iter().enumerate() {
        let ganged = run(
            &wc_opts(&input, root.join(format!("out-n{n}")), 93002 + i as u32)
                .items_per_task(n)
                .workdir(&root),
            &wc_apps(),
            &eng,
        )
        .unwrap();
        assert_eq!(
            redout(&ganged),
            base_bytes,
            "ganged N={n} must be byte-identical to per-task"
        );
        // One batch per task, one persistent launch per batch.
        assert_eq!(ganged.map.tasks.len(), 10usize.div_ceil(n));
        for t in &ganged.map.tasks {
            assert_eq!(t.launches, 1, "N={n}: one launch per batch");
            assert!(t.items <= n, "N={n}: batch bound");
        }
    }
}

#[test]
fn ganged_wordcount_byte_identical_on_sim_exec_engine() {
    let root = tmp("simexec");
    let input = root.join("input");
    write_corpus(&input, 9);

    let local = LocalEngine::new(2);
    let baseline = run(
        &wc_opts(&input, root.join("out-base"), 93011).workdir(&root),
        &wc_apps(),
        &local,
    )
    .unwrap();

    let sim = SimEngine::new(ClusterConfig::with_width(3))
        .execute_payloads(true);
    let ganged = run(
        &wc_opts(&input, root.join("out-sim"), 93012)
            .items_per_task(4)
            .workdir(&root),
        &wc_apps(),
        &sim,
    )
    .unwrap();
    assert_eq!(
        redout(&ganged),
        redout(&baseline),
        "sim-exec ganged output must match local per-task output"
    );
    assert_eq!(ganged.map.tasks.len(), 3, "9 files at N=4 pack 3 batches");
}

#[test]
fn ganged_wordcount_byte_identical_on_remote_engine() {
    let root = tmp("remote");
    let input = root.join("input");
    write_corpus(&input, 10);

    let local = LocalEngine::new(2);
    let baseline = run(
        &wc_opts(&input, root.join("out-base"), 93021).workdir(&root),
        &wc_apps(),
        &local,
    )
    .unwrap();

    let coordinator = RemoteCoordinator::bind(
        "127.0.0.1:0",
        CoordinatorConfig::default(),
    )
    .unwrap();
    let workers = spawn_workers(&coordinator, 2);
    coordinator
        .wait_for_workers(2, Duration::from_secs(10))
        .unwrap();
    let ganged = run(
        &wc_opts(&input, root.join("out-remote"), 93022)
            .items_per_task(4)
            .workdir(&root),
        &wc_apps(),
        &coordinator,
    )
    .unwrap();
    assert_eq!(
        redout(&ganged),
        redout(&baseline),
        "remote ganged output must match local per-task output"
    );
    // Batched tasks really shipped: 10 files at N=4 → 3 assignments.
    assert_eq!(ganged.map.tasks.len(), 3);
    for t in &ganged.map.tasks {
        assert!(t.worker.is_some(), "remote tasks name their worker");
        assert_eq!(t.launches, 1);
    }
    drop(coordinator);
    for w in workers {
        w.join().unwrap().unwrap();
    }
}

#[test]
fn overlap_and_ganging_compose_byte_identically() {
    let root = tmp("overlap");
    let input = root.join("input");
    write_corpus(&input, 8);

    let eng = LocalEngine::new(2);
    let per_task = run(
        &wc_opts(&input, root.join("out-base"), 93031)
            .overlap(true)
            .workdir(&root),
        &wc_apps(),
        &eng,
    )
    .unwrap();
    assert!(per_task.overlapped);

    let ganged_local = run(
        &wc_opts(&input, root.join("out-ganged"), 93032)
            .overlap(true)
            .items_per_task(3)
            .workdir(&root),
        &wc_apps(),
        &eng,
    )
    .unwrap();
    assert!(ganged_local.overlapped, "ganging keeps overlap available");
    assert_eq!(
        ganged_local.partials.as_ref().unwrap().tasks.len(),
        3,
        "one partial fold per batch (8 files at N=3)"
    );
    assert_eq!(redout(&ganged_local), redout(&per_task));

    // And over the wire.
    let coordinator = RemoteCoordinator::bind(
        "127.0.0.1:0",
        CoordinatorConfig::default(),
    )
    .unwrap();
    let workers = spawn_workers(&coordinator, 2);
    coordinator
        .wait_for_workers(2, Duration::from_secs(10))
        .unwrap();
    let ganged_remote = run(
        &wc_opts(&input, root.join("out-remote"), 93033)
            .overlap(true)
            .items_per_task(3)
            .workdir(&root),
        &wc_apps(),
        &coordinator,
    )
    .unwrap();
    assert!(ganged_remote.overlapped);
    assert_eq!(redout(&ganged_remote), redout(&per_task));
    drop(coordinator);
    for w in workers {
        w.join().unwrap().unwrap();
    }
}

#[test]
fn nested_multilevel_ganging_byte_identical() {
    let root = tmp("nested");
    let input = root.join("input");
    for b in 0..3 {
        write_corpus(&input.join(format!("branch-{b}")), 3 + b);
    }
    let mk_opts = |out: &str, pid: u32| {
        Options::new(&input, root.join(out), "wordcount")
            .np(2)
            .reducer("wordcount-reducer")
            .workdir(&root)
            .pid(pid)
    };
    let outer = llmapreduce::apps::registry::resolve_reducer(
        "wordcount-reducer",
    )
    .unwrap();

    let eng = LocalEngine::new(3);
    let per_task = run_nested(
        &mk_opts("out-base", 93041),
        &wc_apps(),
        Some(outer.clone()),
        &eng,
    )
    .unwrap();
    let ganged = run_nested(
        &mk_opts("out-ganged", 93042).items_per_task(2),
        &wc_apps(),
        Some(outer.clone()),
        &eng,
    )
    .unwrap();
    assert_eq!(
        fs::read(per_task.final_out.as_ref().unwrap()).unwrap(),
        fs::read(ganged.final_out.as_ref().unwrap()).unwrap(),
        "nested fan-out must merge identically when ganged"
    );

    let coordinator = RemoteCoordinator::bind(
        "127.0.0.1:0",
        CoordinatorConfig::default(),
    )
    .unwrap();
    let workers = spawn_workers(&coordinator, 3);
    coordinator
        .wait_for_workers(3, Duration::from_secs(10))
        .unwrap();
    let ganged_remote = run_nested(
        &mk_opts("out-remote", 93043).items_per_task(2),
        &wc_apps(),
        Some(outer),
        &coordinator,
    )
    .unwrap();
    assert_eq!(
        fs::read(per_task.final_out.as_ref().unwrap()).unwrap(),
        fs::read(ganged_remote.final_out.as_ref().unwrap()).unwrap(),
        "ganged nested fan-out over the network must merge identically"
    );
    drop(coordinator);
    for w in workers {
        w.join().unwrap().unwrap();
    }
}

// ---------------------------------------------------------------------------
// Chaos: batch reassignment and deterministic retry replay
// ---------------------------------------------------------------------------

/// Kill one of three workers mid-batch (deterministic `--fail-after`):
/// only the dead worker's incomplete batch re-runs, whole, on a
/// survivor; the merged output is unchanged.
#[test]
fn killing_a_worker_mid_batch_reruns_only_its_batch() {
    let root = tmp("chaos");
    let input = root.join("input");
    write_corpus(&input, 12);

    // Local ganged reference for the byte-identity gate.
    let eng = LocalEngine::new(2);
    let reference = run(
        &wc_opts(&input, root.join("out-ref"), 93051)
            .items_per_task(4)
            .workdir(&root),
        &wc_apps(),
        &eng,
    )
    .unwrap();

    let coordinator = RemoteCoordinator::bind(
        "127.0.0.1:0",
        CoordinatorConfig::default(),
    )
    .unwrap();
    let addr = coordinator.local_addr().to_string();
    let survivors = spawn_workers(&coordinator, 2); // w0, w1
    let doomed = {
        let config = WorkerConfig::new(addr)
            .name("doomed")
            .slots(1)
            .fail_after(1);
        std::thread::spawn(move || run_worker(config))
    };
    coordinator
        .wait_for_workers(3, Duration::from_secs(10))
        .unwrap();

    // 12 files at N=4 → 3 batches over 3 idle single-slot workers:
    // least-loaded spread hands the doomed worker exactly one batch,
    // which it drops on receipt.
    let chaotic = run(
        &wc_opts(&input, root.join("out-chaos"), 93052)
            .items_per_task(4)
            .workdir(&root),
        &wc_apps(),
        &coordinator,
    )
    .unwrap();
    assert_eq!(
        redout(&chaotic),
        redout(&reference),
        "output must survive the worker loss unchanged"
    );

    assert_eq!(chaotic.map.tasks.len(), 3);
    let reassigned: Vec<_> = chaotic
        .map
        .tasks
        .iter()
        .filter(|t| t.reassigned > 0)
        .collect();
    assert_eq!(
        reassigned.len(),
        1,
        "exactly the dead worker's batch re-runs"
    );
    assert_eq!(reassigned[0].reassigned, 1, "one extra trip");
    assert_eq!(
        reassigned[0].items, 4,
        "the batch re-runs whole, not item-by-item"
    );
    for t in &chaotic.map.tasks {
        assert_ne!(
            t.worker.as_deref(),
            Some("doomed"),
            "dead workers complete nothing"
        );
    }

    doomed.join().unwrap().unwrap();
    drop(coordinator);
    for w in survivors {
        w.join().unwrap().unwrap();
    }
}

/// Injected-failure retries are a pure function of (seed, task_id,
/// attempt), so a ganged job replays the identical retry pattern on the
/// local engine and the payload-executing simulator — and both match
/// the policy's own prediction.
#[test]
fn ganged_retry_counts_replay_identically_across_engines() {
    let root = tmp("retries");
    let input = root.join("input");
    write_corpus(&input, 10);
    let policy = FailurePolicy {
        failure_rate: 0.6,
        max_retries: 4,
        seed: 0xD1CE,
    };

    let local_eng = LocalEngine::with_policy(2, policy);
    let local = run(
        &wc_opts(&input, root.join("out-local"), 93061)
            .items_per_task(3)
            .workdir(&root),
        &wc_apps(),
        &local_eng,
    )
    .unwrap();

    let sim_eng = SimEngine::new(ClusterConfig {
        failure_rate: policy.failure_rate,
        max_retries: policy.max_retries,
        seed: policy.seed,
        ..ClusterConfig::with_width(2)
    })
    .execute_payloads(true);
    let sim = run(
        &wc_opts(&input, root.join("out-sim"), 93062)
            .items_per_task(3)
            .workdir(&root),
        &wc_apps(),
        &sim_eng,
    )
    .unwrap();

    let retries_of = |r: &MapReduceReport| -> Vec<(usize, usize)> {
        let mut v: Vec<_> = r
            .map
            .tasks
            .iter()
            .map(|t| (t.task_id, t.retries))
            .collect();
        v.sort();
        v
    };
    let local_retries = retries_of(&local);
    assert_eq!(
        local_retries,
        retries_of(&sim),
        "shared FailurePolicy seed must replay the same retries"
    );
    // Both engines also match the policy's closed-form prediction: ten
    // files at N=3 pack four batches (task ids 1..=4), whose retry
    // pattern at this seed is fixed and non-trivial.
    assert_eq!(
        local_retries,
        (1..=4)
            .map(|t| (t, policy.expected_retries(t)))
            .collect::<Vec<_>>()
    );
    assert_eq!(
        local_retries.iter().map(|(_, r)| r).sum::<usize>(),
        6,
        "seed 0xD1CE at rate 0.6 injects retries [0, 2, 1, 3]"
    );
    // Failures + ganging still converge to the same bytes.
    assert_eq!(redout(&local), redout(&sim));
}

// ---------------------------------------------------------------------------
// Bench emission: BENCH_spmd.json schema + monotonicity
// ---------------------------------------------------------------------------

fn validate_spmd_doc(doc: &Json) {
    assert_eq!(
        doc.get("bench").and_then(Json::as_str),
        Some("spmd-amortization")
    );
    assert!(doc.get("source").and_then(Json::as_str).is_some());
    assert!(doc.get("items").and_then(Json::as_usize).is_some());
    assert!(doc.get("startup_us").and_then(Json::as_usize).is_some());
    assert!(doc.get("per_item_us").and_then(Json::as_usize).is_some());
    let points = doc.get("points").and_then(Json::as_arr).unwrap();
    assert!(points.len() >= 2, "at least per-task and one gang size");
    let mut last = usize::MAX;
    let mut seen_per_task = false;
    for p in points {
        let mode = p.get("mode").and_then(Json::as_str).unwrap();
        assert!(mode == "per-task" || mode == "ganged", "{mode}");
        seen_per_task |= mode == "per-task";
        assert!(
            p.get("items_per_task").and_then(Json::as_usize).unwrap() >= 1
        );
        assert!(p.get("launches").and_then(Json::as_usize).is_some());
        assert!(p.get("makespan_us").and_then(Json::as_usize).is_some());
        let o = p
            .get("per_item_launch_overhead_us")
            .and_then(Json::as_usize)
            .unwrap();
        assert!(
            o < last,
            "per-item launch overhead must decrease monotonically \
             as --items-per-task grows ({o} !< {last})"
        );
        last = o;
    }
    assert!(seen_per_task, "the N=1 baseline must be present");
}

#[test]
fn bench_spmd_json_schema_fresh_and_committed() {
    let hint = CostHint {
        startup: Duration::from_millis(128),
        per_item: Duration::from_millis(10),
    };
    let pts =
        spmd_amortization_virtual(64, hint, &[1, 4, 16, 64]).unwrap();
    let doc = spmd_bench_json("sim-virtual", 64, hint, &pts);
    // The emitted text parses back through util::json and validates.
    let fresh = Json::parse(&doc.to_string_pretty()).unwrap();
    validate_spmd_doc(&fresh);
    // The amortization arithmetic is exact: launches × startup / items.
    let overheads: Vec<usize> = fresh
        .get("points")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .map(|p| {
            p.get("per_item_launch_overhead_us")
                .and_then(Json::as_usize)
                .unwrap()
        })
        .collect();
    assert_eq!(overheads, vec![128_000, 32_000, 8_000, 2_000]);

    // The committed repo-root artifact stays in lockstep with the
    // generator (same schema, same virtual-time values).
    let committed = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap()
        .join("BENCH_spmd.json");
    if committed.is_file() {
        let text = fs::read_to_string(&committed).unwrap();
        let doc2 = Json::parse(&text).unwrap();
        validate_spmd_doc(&doc2);
        assert_eq!(
            doc2, fresh,
            "committed BENCH_spmd.json diverged from the generator; \
             re-run `llmapreduce bench spmd` at the repo root"
        );
    }
}
