//! Integration tests for the handle-based invocation API: concurrent
//! sessions over one shared engine, nested fan-out equivalence, and
//! drop-without-wait cleanup — all through the public API.

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use llmapreduce::apps::{MapApp, MapInstance, ReduceApp};
use llmapreduce::mapreduce::multilevel::run_nested;
use llmapreduce::mapreduce::run;
use llmapreduce::prelude::*;
use llmapreduce::scheduler::sim::{ClusterConfig, SimEngine};

fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("llmr-sess-{tag}-{}", std::process::id()));
    let _ = fs::remove_dir_all(&d);
    fs::create_dir_all(&d).unwrap();
    d
}

fn write_inputs(dir: &Path, n: usize, tag: &str) {
    fs::create_dir_all(dir).unwrap();
    for i in 0..n {
        fs::write(dir.join(format!("{tag}-{i:02}.txt")), format!("{tag} {i}\n"))
            .unwrap();
    }
}

/// Mapper that appends a marker, counts completions, and optionally
/// blocks on a gate until the test opens it.
struct TestMapApp {
    gate: Option<Arc<AtomicBool>>,
    completed: Arc<AtomicUsize>,
}

struct TestMapInstance {
    gate: Option<Arc<AtomicBool>>,
    completed: Arc<AtomicUsize>,
}

impl TestMapApp {
    fn free(completed: &Arc<AtomicUsize>) -> Arc<dyn MapApp> {
        Arc::new(TestMapApp {
            gate: None,
            completed: completed.clone(),
        })
    }

    fn gated(
        gate: &Arc<AtomicBool>,
        completed: &Arc<AtomicUsize>,
    ) -> Arc<dyn MapApp> {
        Arc::new(TestMapApp {
            gate: Some(gate.clone()),
            completed: completed.clone(),
        })
    }
}

impl MapApp for TestMapApp {
    fn name(&self) -> &str {
        "test-map"
    }

    fn startup(&self) -> Result<Box<dyn MapInstance>> {
        Ok(Box::new(TestMapInstance {
            gate: self.gate.clone(),
            completed: self.completed.clone(),
        }))
    }
}

impl MapInstance for TestMapInstance {
    fn process(&mut self, input: &Path, output: &Path) -> Result<()> {
        if let Some(gate) = &self.gate {
            let deadline = Instant::now() + Duration::from_secs(30);
            while !gate.load(Ordering::SeqCst) {
                if Instant::now() > deadline {
                    return Err(Error::App {
                        app: "test-map".into(),
                        input: input.to_path_buf(),
                        reason: "gate never opened".into(),
                    });
                }
                std::thread::yield_now();
            }
        }
        let data = fs::read_to_string(input)
            .map_err(|e| Error::io(input.to_path_buf(), e))?;
        fs::write(output, format!("{data}#mapped\n"))
            .map_err(|e| Error::io(output.to_path_buf(), e))?;
        self.completed.fetch_add(1, Ordering::SeqCst);
        Ok(())
    }
}

/// Deterministic reducer: concatenates the directory's files in sorted
/// order (excluding its own output), partial-fold capable.
struct SortedConcat;

impl ReduceApp for SortedConcat {
    fn name(&self) -> &str {
        "sorted-concat"
    }

    fn supports_partial(&self) -> bool {
        true
    }

    fn reduce(&self, dir: &Path, out: &Path) -> Result<()> {
        let mut files: Vec<PathBuf> = fs::read_dir(dir)
            .map_err(|e| Error::io(dir.to_path_buf(), e))?
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| p.is_file() && *p != *out)
            .collect();
        files.sort();
        let mut merged = String::new();
        for f in &files {
            merged.push_str(
                &fs::read_to_string(f).map_err(|e| Error::io(f.clone(), e))?,
            );
        }
        fs::write(out, merged).map_err(|e| Error::io(out.to_path_buf(), e))
    }
}

// ---------------------------------------------------------------------------
// The acceptance-criterion test: submit returns pre-execution
// ---------------------------------------------------------------------------

#[test]
fn submit_returns_before_any_task_executes() {
    let root = tmp("pre-exec");
    let input = root.join("input");
    write_inputs(&input, 4, "a");
    let gate = Arc::new(AtomicBool::new(false));
    let completed = Arc::new(AtomicUsize::new(0));
    let apps = Apps {
        mapper: TestMapApp::gated(&gate, &completed),
        reducer: None,
    };
    let engine = LocalEngine::new(2);
    let session = Session::new(&engine);
    let opts = Options::new(&input, root.join("output"), "test-map")
        .np(2)
        .workdir(&root)
        .pid(95001);

    // The gate is closed: no task can complete until the test opens it,
    // so a submit() that executed (or waited on) the work would hang.
    // It returns instead, with the whole chain pending.
    let inv = session.submit(&opts, &apps).unwrap();
    assert_eq!(
        completed.load(Ordering::SeqCst),
        0,
        "submit() must return before any task executed"
    );
    assert_eq!(inv.status(), InvocationStatus::Running);

    gate.store(true, Ordering::SeqCst);
    let report = inv.wait().unwrap();
    assert_eq!(report.map.total_items(), 4);
    assert_eq!(completed.load(Ordering::SeqCst), 4);
}

// ---------------------------------------------------------------------------
// N invocations in flight on one engine
// ---------------------------------------------------------------------------

#[test]
fn many_invocations_before_any_wait_all_complete() {
    let root = tmp("fanout");
    let completed = Arc::new(AtomicUsize::new(0));
    let apps = Apps {
        mapper: TestMapApp::free(&completed),
        reducer: Some(Arc::new(SortedConcat)),
    };
    let engine = LocalEngine::new(2);
    let session = Session::new(&engine);

    let mut pending = Vec::new();
    for k in 0..4u32 {
        let input = root.join(format!("input-{k}"));
        write_inputs(&input, 3, &format!("j{k}"));
        let opts = Options::new(
            &input,
            root.join(format!("output-{k}")),
            "test-map",
        )
        .np(3)
        .reducer("sorted-concat")
        .workdir(&root)
        .pid(95100 + k);
        pending.push((k, session.submit(&opts, &apps).unwrap()));
    }

    // Everything is submitted before the first wait; wait_all drains the
    // session, then every handle's wait returns promptly.
    session.wait_all().unwrap();
    for (k, inv) in pending {
        assert_eq!(inv.status(), InvocationStatus::Succeeded);
        let report = inv.wait().unwrap();
        assert_eq!(report.map.total_items(), 3, "invocation {k}");
        let merged = fs::read_to_string(report.redout_path.unwrap()).unwrap();
        assert_eq!(merged.matches("#mapped").count(), 3);
    }
    assert_eq!(completed.load(Ordering::SeqCst), 12);
}

#[test]
fn one_session_shared_across_threads() {
    let root = tmp("threads");
    let completed = Arc::new(AtomicUsize::new(0));
    let apps = Apps {
        mapper: TestMapApp::free(&completed),
        reducer: None,
    };
    let engine = LocalEngine::new(2);
    let session = Session::new(&engine);

    let mut opt_sets = Vec::new();
    for k in 0..3u32 {
        let input = root.join(format!("input-{k}"));
        write_inputs(&input, 2, &format!("t{k}"));
        opt_sets.push(
            Options::new(
                &input,
                root.join(format!("output-{k}")),
                "test-map",
            )
            .np(2)
            .workdir(&root)
            .pid(95150 + k),
        );
    }

    std::thread::scope(|scope| {
        for opts in &opt_sets {
            let session = &session;
            let apps = &apps;
            scope.spawn(move || {
                let report =
                    session.submit(opts, apps).unwrap().wait().unwrap();
                assert_eq!(report.map.total_items(), 2);
            });
        }
    });
    assert_eq!(completed.load(Ordering::SeqCst), 6);
}

// ---------------------------------------------------------------------------
// Concurrent nested fan-out == serial reference, byte for byte
// ---------------------------------------------------------------------------

#[test]
fn nested_concurrent_output_matches_serial_reference() {
    let root = tmp("nested-equiv");
    let input = root.join("input");
    for k in 0..4 {
        write_inputs(
            &input.join(format!("branch-{k}")),
            3,
            &format!("b{k}"),
        );
    }
    let completed = Arc::new(AtomicUsize::new(0));
    let apps = Apps {
        mapper: TestMapApp::free(&completed),
        reducer: Some(Arc::new(SortedConcat)),
    };

    // Concurrent path: run_nested submits all four inner pipelines up
    // front on one engine.
    let engine = LocalEngine::new(3);
    let opts = Options::new(&input, root.join("out-concurrent"), "test-map")
        .np(2)
        .reducer("sorted-concat")
        .workdir(&root)
        .pid(95300);
    let nested =
        run_nested(&opts, &apps, Some(Arc::new(SortedConcat)), &engine)
            .unwrap();
    let concurrent = fs::read_to_string(nested.final_out.unwrap()).unwrap();

    // Serial reference: the seed's behaviour — one blocking inner run
    // per subdirectory, then the same collect-and-merge by hand.
    let serial_engine = LocalEngine::new(3);
    let serial_out_root = root.join("out-serial");
    let collect = root.join("serial-collect");
    fs::create_dir_all(&collect).unwrap();
    let mut subdirs: Vec<PathBuf> = fs::read_dir(&input)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.is_dir())
        .collect();
    subdirs.sort();
    for (k, sub) in subdirs.iter().enumerate() {
        let name = sub.file_name().unwrap().to_str().unwrap().to_string();
        let inner_opts = Options::new(
            sub,
            serial_out_root.join(&name),
            "test-map",
        )
        .np(2)
        .reducer("sorted-concat")
        .workdir(&root)
        .pid(95400 + k as u32);
        let report = run(&inner_opts, &apps, &serial_engine).unwrap();
        fs::copy(
            report.redout_path.unwrap(),
            collect.join(format!("{name}.part")),
        )
        .unwrap();
    }
    let serial_final = serial_out_root.join("llmapreduce.out");
    SortedConcat.reduce(&collect, &serial_final).unwrap();
    let serial = fs::read_to_string(&serial_final).unwrap();

    assert_eq!(
        concurrent, serial,
        "concurrent fan-out must not change the final reduce output"
    );
    assert_eq!(concurrent.matches("#mapped").count(), 12);
}

// ---------------------------------------------------------------------------
// Drop-without-wait: no deadlock, no leaked scratch
// ---------------------------------------------------------------------------

#[test]
fn dropped_invocation_cleans_scratch_and_engine_survives() {
    let root = tmp("dropped");
    let input = root.join("input");
    write_inputs(&input, 4, "d");
    let completed = Arc::new(AtomicUsize::new(0));
    let apps = Apps {
        mapper: TestMapApp::free(&completed),
        reducer: Some(Arc::new(SortedConcat)),
    };
    let engine = LocalEngine::new(2);
    let session = Session::new(&engine);
    let output = root.join("output");
    let opts = Options::new(&input, &output, "test-map")
        .np(2)
        .reducer("sorted-concat")
        .overlap(true)
        .workdir(&root)
        .pid(95500);

    let inv = session.submit(&opts, &apps).unwrap();
    drop(inv); // never waited: blocks until the chain settles, then cleans

    assert!(
        !root.join(".MAPRED.95500").exists(),
        "dropped invocation must not leak its .MAPRED dir"
    );
    assert!(
        !output.join(".partials.95500").exists(),
        "dropped invocation must not leak its partials staging"
    );
    // The jobs really ran to completion before cleanup.
    assert_eq!(completed.load(Ordering::SeqCst), 4);
    assert!(output.join("llmapreduce.out").is_file());

    // The engine is unaffected: it keeps serving new invocations.
    let opts2 = Options::new(&input, root.join("output-2"), "test-map")
        .np(2)
        .workdir(&root)
        .pid(95501);
    let report = run(&opts2, &apps, &engine).unwrap();
    assert_eq!(report.map.total_items(), 4);
}

// ---------------------------------------------------------------------------
// Shared SimEngine stays deterministic under the session API
// ---------------------------------------------------------------------------

#[test]
fn shared_sim_engine_is_deterministic_across_sessions() {
    let run_pair = |tag: &str| -> (Duration, Duration) {
        let root = tmp(tag);
        let completed = Arc::new(AtomicUsize::new(0));
        let apps = Apps {
            mapper: TestMapApp::free(&completed),
            reducer: Some(Arc::new(SortedConcat)),
        };
        let engine = SimEngine::new(ClusterConfig::with_width(2))
            .execute_payloads(true);
        let session = Session::new(&engine);
        let mut invs = Vec::new();
        for k in 0..2u32 {
            let input = root.join(format!("input-{k}"));
            write_inputs(&input, 3, "s");
            let opts = Options::new(
                &input,
                root.join(format!("output-{k}")),
                "test-map",
            )
            .np(3)
            .reducer("sorted-concat")
            .workdir(&root)
            .pid(95600 + k);
            invs.push(session.submit(&opts, &apps).unwrap());
        }
        let b = invs.pop().unwrap();
        let a = invs.pop().unwrap();
        // Waited out of submission order on purpose.
        let eb = b.wait().unwrap().elapsed();
        let ea = a.wait().unwrap().elapsed();
        (ea, eb)
    };
    assert_eq!(
        run_pair("sim-det-1"),
        run_pair("sim-det-2"),
        "virtual clocks must replay identically under concurrent sessions"
    );
}
