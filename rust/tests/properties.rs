//! Property-based tests over the coordinator's invariants.
//!
//! `proptest` is unavailable offline, so this uses the crate's own
//! deterministic RNG as a case generator: each property runs over a few
//! hundred random shapes, and a failing case prints its seed so it can be
//! replayed exactly.

use std::collections::HashSet;
use std::time::Duration;

use llmapreduce::mapreduce::distribution::distribute;
use llmapreduce::mapreduce::planner::{plan, task_count};
use llmapreduce::options::{AppType, Distribution, Options, SchedulerKind};
use llmapreduce::scheduler::dialect::dialect_for;
use llmapreduce::scheduler::sim::{ClusterConfig, SimEngine};
use llmapreduce::scheduler::{Engine, JobSpec, TaskSpec, TaskWork};
use llmapreduce::util::json::{obj, Json};
use llmapreduce::util::rng::Rng;
use llmapreduce::workdir::scan::InputFile;

const CASES: usize = 300;

/// Tiny property harness: runs `f` over CASES seeded RNGs; panics with
/// the failing seed embedded in the message.
fn forall(name: &str, mut f: impl FnMut(&mut Rng)) {
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(0xC0FFEE ^ seed);
        let result = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| f(&mut rng)),
        );
        if let Err(e) = result {
            panic!(
                "property '{name}' failed at seed {seed}: {:?}",
                e.downcast_ref::<String>()
                    .cloned()
                    .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Distribution invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_distribution_is_a_partition() {
    forall("partition", |rng| {
        let nfiles = rng.range(0, 2000);
        let ntasks = rng.range(1, 300);
        let dist = if rng.next_below(2) == 0 {
            Distribution::Block
        } else {
            Distribution::Cyclic
        };
        let a = distribute(nfiles, ntasks, dist);
        assert_eq!(a.len(), ntasks);
        let mut seen = HashSet::new();
        for idx in a.iter().flatten() {
            assert!(*idx < nfiles, "index in range");
            assert!(seen.insert(*idx), "no duplicates");
        }
        assert_eq!(seen.len(), nfiles, "complete coverage");
    });
}

#[test]
fn prop_distribution_balanced() {
    forall("balance", |rng| {
        let nfiles = rng.range(0, 5000);
        let ntasks = rng.range(1, 257);
        for dist in [Distribution::Block, Distribution::Cyclic] {
            let a = distribute(nfiles, ntasks, dist);
            let min = a.iter().map(Vec::len).min().unwrap();
            let max = a.iter().map(Vec::len).max().unwrap();
            assert!(max - min <= 1, "{dist:?}: {min}..{max}");
        }
    });
}

#[test]
fn prop_block_is_contiguous_and_ordered() {
    forall("block-contiguous", |rng| {
        let nfiles = rng.range(0, 3000);
        let ntasks = rng.range(1, 64);
        let a = distribute(nfiles, ntasks, Distribution::Block);
        let flat: Vec<usize> = a.iter().flatten().copied().collect();
        assert_eq!(flat, (0..nfiles).collect::<Vec<_>>());
    });
}

#[test]
fn prop_cyclic_has_stride_ntasks() {
    forall("cyclic-stride", |rng| {
        let nfiles = rng.range(1, 3000);
        let ntasks = rng.range(1, 64);
        let a = distribute(nfiles, ntasks, Distribution::Cyclic);
        for (t, files) in a.iter().enumerate() {
            for (k, idx) in files.iter().enumerate() {
                assert_eq!(*idx, t + k * ntasks);
            }
        }
    });
}

// ---------------------------------------------------------------------------
// Planner invariants
// ---------------------------------------------------------------------------

fn fake_files(n: usize) -> Vec<InputFile> {
    (0..n)
        .map(|i| InputFile {
            path: format!("/in/f{i:05}").into(),
            relative: format!("f{i:05}").into(),
        })
        .collect()
}

#[test]
fn prop_planner_covers_every_file_once() {
    let dialect = dialect_for(SchedulerKind::GridEngine);
    forall("planner-cover", |rng| {
        let nfiles = rng.range(1, 800);
        let mut opts = Options::new("/in", "/out", "m");
        match rng.next_below(3) {
            0 => {} // DEFAULT
            1 => opts.np = Some(rng.range(1, 300)),
            _ => opts.ndata = Some(rng.range(1, 50)),
        }
        if rng.next_below(2) == 0 {
            opts.distribution = Distribution::Cyclic;
        }
        let p = plan(&fake_files(nfiles), &opts, dialect.as_ref()).unwrap();
        let all: Vec<_> =
            p.tasks.iter().flat_map(|t| t.pairs.iter()).collect();
        assert_eq!(all.len(), nfiles);
        let inputs: HashSet<_> = all.iter().map(|(i, _)| i).collect();
        assert_eq!(inputs.len(), nfiles, "each input exactly once");
        // Outputs all distinct and inside the output dir.
        let outputs: HashSet<_> = all.iter().map(|(_, o)| o).collect();
        assert_eq!(outputs.len(), nfiles);
        for (_, o) in &all {
            assert!(o.starts_with("/out"));
        }
    });
}

#[test]
fn prop_ndata_bounds_files_per_task() {
    let dialect = dialect_for(SchedulerKind::GridEngine);
    forall("ndata-bound", |rng| {
        let nfiles = rng.range(1, 2000);
        let ndata = rng.range(1, 64);
        let opts = Options::new("/in", "/out", "m").ndata(ndata);
        let p = plan(&fake_files(nfiles), &opts, dialect.as_ref()).unwrap();
        assert!(p.max_files_per_task() <= ndata);
    });
}

#[test]
fn prop_task_count_never_exceeds_dialect_limit() {
    forall("limit", |rng| {
        let kind = match rng.next_below(3) {
            0 => SchedulerKind::GridEngine,
            1 => SchedulerKind::Slurm,
            _ => SchedulerKind::Lsf,
        };
        let dialect = dialect_for(kind);
        let nfiles = rng.range(1, 200_000);
        let np = rng.range(1, 999);
        let opts = Options::new("/in", "/out", "m").np(np);
        match task_count(nfiles, &opts, dialect.as_ref()) {
            Ok(t) => assert!(t <= dialect.max_array_tasks()),
            Err(e) => {
                assert!(np > dialect.max_array_tasks(), "{kind:?}: {e}")
            }
        }
    });
}

#[test]
fn prop_mimo_launches_at_most_tasks() {
    let dialect = dialect_for(SchedulerKind::GridEngine);
    forall("mimo-launches", |rng| {
        let nfiles = rng.range(1, 1000);
        let np = rng.range(1, 128);
        let siso = Options::new("/in", "/out", "m").np(np);
        let mimo = siso.clone().apptype(AppType::Mimo);
        let ps = plan(&fake_files(nfiles), &siso, dialect.as_ref()).unwrap();
        let pm = plan(&fake_files(nfiles), &mimo, dialect.as_ref()).unwrap();
        assert_eq!(ps.total_launches(), nfiles, "SISO: launch per file");
        assert!(pm.total_launches() <= np.min(nfiles));
        assert!(pm.total_launches() >= 1);
    });
}

// ---------------------------------------------------------------------------
// SPMD batch-packer invariants (mapreduce::planner::pack_batches)
// ---------------------------------------------------------------------------

use llmapreduce::mapreduce::planner::pack_batches;

/// Satellite invariant: the packer emits every item exactly once, in
/// order, within batch-size bounds — for arbitrary item counts and gang
/// sizes including N=1, N > items, and uneven tails.
#[test]
fn prop_pack_batches_exact_cover_in_order() {
    forall("pack-cover", |rng| {
        let nitems = rng.range(0, 5000);
        let n = rng.range(1, 600);
        let batches = pack_batches(nitems, n);
        // Flattening reproduces 0..nitems exactly: every item once, in
        // order, within and across batches.
        let flat: Vec<usize> = batches.iter().cloned().flatten().collect();
        assert_eq!(flat, (0..nitems).collect::<Vec<_>>());
        for b in &batches {
            assert!(!b.is_empty(), "no empty batches");
            assert!(b.len() <= n, "batch of {} exceeds N={n}", b.len());
        }
        // Only the tail may run short.
        for b in batches.iter().rev().skip(1) {
            assert_eq!(b.len(), n, "only the last batch may be uneven");
        }
        assert_eq!(batches.len(), nitems.div_ceil(n));
    });
}

#[test]
fn prop_pack_batches_edge_shapes() {
    forall("pack-edges", |rng| {
        let nitems = rng.range(1, 2000);
        // N=1: one item per batch.
        assert_eq!(pack_batches(nitems, 1).len(), nitems);
        // N >= items: a single batch holding everything.
        let big = pack_batches(nitems, nitems + rng.range(0, 100));
        assert_eq!(big.len(), 1);
        assert_eq!(big[0].len(), nitems);
        // Zero items: nothing to pack.
        assert!(pack_batches(0, rng.range(1, 100)).is_empty());
        // N=0 is clamped to 1, not a panic or an infinite loop.
        assert_eq!(pack_batches(nitems, 0).len(), nitems);
    });
}

// ---------------------------------------------------------------------------
// Options parsing
// ---------------------------------------------------------------------------

#[test]
fn prop_options_roundtrip_through_args() {
    forall("options-roundtrip", |rng| {
        let np = rng.range(1, 100_000);
        let ndata = rng.range(1, 10_000);
        let exts = ["out", "gray", "result", "x"];
        let ext = exts[rng.next_below(exts.len() as u64) as usize];
        let args = vec![
            format!("--np={np}"),
            format!("--ndata={ndata}"),
            "--input=/data/in".to_string(),
            "--output=/data/out".to_string(),
            "--mapper=myMapper".to_string(),
            format!("--ext={ext}"),
            format!(
                "--distribution={}",
                if rng.next_below(2) == 0 { "block" } else { "cyclic" }
            ),
            format!(
                "--apptype={}",
                if rng.next_below(2) == 0 { "siso" } else { "mimo" }
            ),
        ];
        let o = Options::parse_args(&args).unwrap();
        assert_eq!(o.np, Some(np));
        assert_eq!(o.ndata, Some(ndata));
        assert_eq!(o.ext, ext);
    });
}

// ---------------------------------------------------------------------------
// Simulator invariants
// ---------------------------------------------------------------------------

fn random_tasks(rng: &mut Rng, n: usize) -> Vec<TaskSpec> {
    (0..n)
        .map(|i| TaskSpec {
            task_id: i + 1,
            work: TaskWork::Synthetic {
                startup: Duration::from_micros(rng.range(1, 5000) as u64),
                per_item: Duration::from_micros(rng.range(1, 2000) as u64),
                items: rng.range(1, 20),
                launches: rng.range(1, 20),
            },
        })
        .collect()
}

#[test]
fn prop_sim_deterministic_replay() {
    forall("sim-replay", |rng| {
        let n = rng.range(1, 60);
        let seed = rng.next_u64();
        let tasks = random_tasks(rng, n);
        let run = |tasks: Vec<TaskSpec>| {
            let eng = SimEngine::new(ClusterConfig {
                jitter: 0.1,
                seed,
                ..ClusterConfig::with_width(rng_width(seed))
            });
            eng.run(JobSpec::new("j", tasks)).unwrap().makespan
        };
        assert_eq!(run(tasks.clone()), run(tasks));
    });
}

fn rng_width(seed: u64) -> usize {
    (seed % 16) as usize + 1
}

#[test]
fn prop_sim_wider_cluster_never_slower() {
    forall("sim-monotone-width", |rng| {
        let n = rng.range(1, 80);
        let tasks = random_tasks(rng, n);
        let run = |np: usize, tasks: Vec<TaskSpec>| {
            let eng = SimEngine::new(ClusterConfig {
                dispatch_latency: Duration::from_micros(100),
                ..ClusterConfig::with_width(np)
            });
            eng.run(JobSpec::new("j", tasks)).unwrap().makespan
        };
        let narrow = run(1, tasks.clone());
        let wide = run(64, tasks);
        assert!(
            wide <= narrow,
            "wider cluster can't be slower: {wide:?} vs {narrow:?}"
        );
    });
}

#[test]
fn prop_sim_makespan_bounds() {
    // Makespan >= the longest single task; <= serial sum + dispatch.
    forall("sim-bounds", |rng| {
        let n = rng.range(1, 40);
        let tasks = random_tasks(rng, n);
        let durations: Vec<Duration> = tasks
            .iter()
            .map(|t| match &t.work {
                TaskWork::Synthetic {
                    startup,
                    per_item,
                    items,
                    launches,
                } => *startup * (*launches as u32)
                    + *per_item * (*items as u32),
                _ => unreachable!(),
            })
            .collect();
        let dispatch = Duration::from_micros(50);
        let np = rng.range(1, 32);
        let eng = SimEngine::new(ClusterConfig {
            dispatch_latency: dispatch,
            ..ClusterConfig::with_width(np)
        });
        let makespan =
            eng.run(JobSpec::new("j", tasks)).unwrap().makespan;
        let longest = durations.iter().max().copied().unwrap();
        let serial: Duration =
            durations.iter().sum::<Duration>() + dispatch * n as u32;
        assert!(makespan >= longest, "{makespan:?} >= {longest:?}");
        assert!(makespan <= serial, "{makespan:?} <= {serial:?}");
    });
}

#[test]
fn prop_sim_mimo_never_slower_than_siso() {
    forall("sim-mimo-wins", |rng| {
        let np = rng.range(1, 64);
        let nfiles = rng.range(np, 1000);
        let startup = Duration::from_micros(rng.range(10, 10_000) as u64);
        let per_item = Duration::from_micros(rng.range(1, 5_000) as u64);
        let base = nfiles / np;
        let rem = nfiles % np;
        let mk = |mimo: bool| -> Vec<TaskSpec> {
            (0..np)
                .map(|t| {
                    let items = base + usize::from(t < rem);
                    TaskSpec {
                        task_id: t + 1,
                        work: TaskWork::Synthetic {
                            startup,
                            per_item,
                            items,
                            launches: if mimo {
                                usize::from(items > 0)
                            } else {
                                items
                            },
                        },
                    }
                })
                .collect()
        };
        let run = |tasks| {
            SimEngine::new(ClusterConfig::with_width(np))
                .run(JobSpec::new("j", tasks))
                .unwrap()
                .makespan
        };
        assert!(run(mk(true)) <= run(mk(false)));
    });
}

// ---------------------------------------------------------------------------
// JSON roundtrip over random documents
// ---------------------------------------------------------------------------

fn random_json(rng: &mut Rng, depth: usize) -> Json {
    if depth == 0 {
        return match rng.next_below(4) {
            0 => Json::Null,
            1 => Json::Bool(rng.next_below(2) == 0),
            2 => Json::Num((rng.next_below(1_000_000) as f64) / 4.0),
            _ => Json::Str(format!("s{}", rng.next_below(10_000))),
        };
    }
    match rng.next_below(2) {
        0 => Json::Arr(
            (0..rng.range(0, 5))
                .map(|_| random_json(rng, depth - 1))
                .collect(),
        ),
        _ => obj((0..rng.range(0, 5))
            .map(|i| {
                let key = format!("k{i}");
                (
                    Box::leak(key.into_boxed_str()) as &str,
                    random_json(rng, depth - 1),
                )
            })
            .collect()),
    }
}

#[test]
fn prop_json_roundtrip() {
    forall("json-roundtrip", |rng| {
        let doc = random_json(rng, 3);
        let compact = Json::parse(&doc.to_string_compact()).unwrap();
        let pretty = Json::parse(&doc.to_string_pretty()).unwrap();
        assert_eq!(doc, compact);
        assert_eq!(doc, pretty);
    });
}

// ---------------------------------------------------------------------------
// Remote wire-protocol invariants (scheduler::remote::protocol)
// ---------------------------------------------------------------------------

use llmapreduce::error::Error;
use llmapreduce::scheduler::remote::protocol::{
    Message, TaskAssign, TaskComplete, WireMode, WireOutcome, WireWork,
    PROTOCOL_VERSION,
};

/// Random path-ish / name-ish string exercising every escape class the
/// JSON layer handles: spaces, quotes, backslashes, newlines, tabs,
/// control chars, multi-byte UTF-8.
fn random_wire_string(rng: &mut Rng) -> String {
    const ALPHABET: &[&str] = &[
        "a", "Z", "0", "/", ".", "-", "_", " ", "\"", "\\", "\n", "\t",
        "\r", "\u{1}", "é", "日", "😀", ":", "{", "}", "[", "]", ",",
    ];
    (0..rng.range(0, 24))
        .map(|_| ALPHABET[rng.range(0, ALPHABET.len() - 1)])
        .collect()
}

fn random_wire_work(rng: &mut Rng) -> WireWork {
    match rng.next_below(4) {
        0 => WireWork::Map {
            mapper: random_wire_string(rng),
            pairs: (0..rng.range(0, 6))
                .map(|_| {
                    (random_wire_string(rng), random_wire_string(rng))
                })
                .collect(),
            mode: ["siso", "mimo", "spmd"][rng.range(0, 2)].to_string(),
        },
        1 => WireWork::Reduce {
            reducer: random_wire_string(rng),
            input_dir: random_wire_string(rng),
            out_file: random_wire_string(rng),
        },
        2 => WireWork::ReducePartial {
            reducer: random_wire_string(rng),
            files: (0..rng.range(0, 6))
                .map(|_| random_wire_string(rng))
                .collect(),
            out_file: random_wire_string(rng),
        },
        _ => WireWork::Synthetic {
            startup_us: rng.next_below(10_000_000),
            per_item_us: rng.next_below(10_000_000),
            items: rng.range(0, 100_000),
            launches: rng.range(0, 100_000),
        },
    }
}

/// Independently present-or-absent worker timestamp, as sent by a
/// mixed-version fleet (PR 9 stamps are optional on the wire).
fn random_opt_us(rng: &mut Rng) -> Option<u64> {
    (rng.next_below(2) == 1).then(|| rng.next_below(1 << 40))
}

/// Independently absent / json / binary wire preference, as advertised
/// (or not, by pre-PR-10 peers) in registration frames.
fn random_wire_mode(rng: &mut Rng) -> Option<WireMode> {
    match rng.next_below(3) {
        0 => None,
        1 => Some(WireMode::Json),
        _ => Some(WireMode::Binary),
    }
}

fn random_outcome(rng: &mut Rng) -> WireOutcome {
    WireOutcome {
        startup_us: rng.next_below(1 << 40),
        compute_us: rng.next_below(1 << 40),
        launches: rng.range(0, 100_000),
        items: rng.range(0, 100_000),
        recv_us: random_opt_us(rng),
        exec_start_us: random_opt_us(rng),
        exec_end_us: random_opt_us(rng),
    }
}

fn random_assign(rng: &mut Rng) -> TaskAssign {
    TaskAssign {
        job: rng.next_below(1 << 40),
        task_idx: rng.range(0, 100_000),
        task_id: rng.range(0, 100_000),
        work: random_wire_work(rng),
    }
}

fn random_message(rng: &mut Rng) -> Message {
    match rng.next_below(11) {
        0 => Message::Register {
            name: random_wire_string(rng),
            slots: rng.range(0, 1 << 20),
            version: PROTOCOL_VERSION,
            wire: random_wire_mode(rng),
        },
        1 => Message::Registered {
            worker_id: rng.next_below(1 << 40),
            wire: random_wire_mode(rng),
        },
        2 => Message::Heartbeat {
            worker_id: rng.next_below(1 << 40),
            sent_us: random_opt_us(rng),
            rtt_us: random_opt_us(rng),
        },
        3 => Message::Assign {
            job: rng.next_below(1 << 40),
            task_idx: rng.range(0, 100_000),
            task_id: rng.range(0, 100_000),
            work: random_wire_work(rng),
        },
        4 => Message::Complete {
            job: rng.next_below(1 << 40),
            task_idx: rng.range(0, 100_000),
            outcome: random_outcome(rng),
        },
        5 => Message::Failed {
            job: rng.next_below(1 << 40),
            task_idx: rng.range(0, 100_000),
            msg: random_wire_string(rng),
        },
        6 => Message::HeartbeatAck {
            echo_us: rng.next_below(1 << 40),
        },
        7 => Message::AssignBatch {
            tasks: (0..rng.range(0, 5))
                .map(|_| random_assign(rng))
                .collect(),
        },
        8 => Message::CompleteBatch {
            done: (0..rng.range(0, 5))
                .map(|_| TaskComplete {
                    job: rng.next_below(1 << 40),
                    task_idx: rng.range(0, 100_000),
                    outcome: random_outcome(rng),
                })
                .collect(),
        },
        9 => Message::Revoke {
            job: rng.next_below(1 << 40),
            task_idx: rng.range(0, 100_000),
        },
        _ => Message::Shutdown,
    }
}

/// Satellite invariant (PR 9): frames from a pre-PR-9 peer — no
/// `sent_us`/`rtt_us` on heartbeats, no worker stamps in outcomes —
/// decode on a current build with the optional fields `None`, whatever
/// the required fields hold.  No coordinator/worker version lockstep.
#[test]
fn prop_legacy_frames_decode_without_timestamps() {
    forall("wire-legacy", |rng| {
        let (wid, job) =
            (rng.next_below(1 << 40), rng.next_below(1 << 40));
        let (su, cu) = (rng.next_below(1 << 40), rng.next_below(1 << 40));
        let (tidx, launches, items) = (
            rng.range(0, 100_000),
            rng.range(0, 100_000),
            rng.range(0, 100_000),
        );
        let hb = format!(r#"{{"type":"heartbeat","worker_id":{wid}}}"#);
        assert_eq!(
            Message::decode(&hb).unwrap(),
            Message::Heartbeat {
                worker_id: wid,
                sent_us: None,
                rtt_us: None,
            }
        );
        let done = format!(
            r#"{{"type":"complete","job":{job},"task_idx":{tidx},"outcome":{{"startup_us":{su},"compute_us":{cu},"launches":{launches},"items":{items}}}}}"#
        );
        assert_eq!(
            Message::decode(&done).unwrap(),
            Message::Complete {
                job,
                task_idx: tidx,
                outcome: WireOutcome {
                    startup_us: su,
                    compute_us: cu,
                    launches,
                    items,
                    recv_us: None,
                    exec_start_us: None,
                    exec_end_us: None,
                },
            }
        );
    });
}

/// Satellite invariant: every protocol message survives the
/// encode→decode trip bit-identically, whatever strings it carries.
#[test]
fn prop_wire_messages_roundtrip() {
    forall("wire-roundtrip", |rng| {
        let msg = random_message(rng);
        let line = msg.encode();
        let back = Message::decode(&line)
            .unwrap_or_else(|e| panic!("decode failed: {e}\n{line}"));
        assert_eq!(back, msg, "frame: {line}");
    });
}

/// Mangled frames must come back as `Error::Format` — never a panic,
/// never a silently-wrong message.
#[test]
fn prop_malformed_frames_fail_cleanly() {
    forall("wire-malformed", |rng| {
        let line = random_message(rng).encode();
        // Truncate mid-frame (always invalid: dropping at least the
        // closing brace and newline leaves unterminated JSON).
        let nchars = line.chars().count();
        let cut = rng.range(0, nchars.saturating_sub(2));
        let truncated: String = line.chars().take(cut).collect();
        match Message::decode(&truncated) {
            Err(Error::Format { kind: "wire", .. }) => {}
            Err(other) => panic!("wrong error kind: {other}"),
            Ok(m) => panic!("truncated frame decoded as {m:?}"),
        }
        // Random byte soup.
        let soup = random_wire_string(rng);
        if let Err(e) = Message::decode(&soup) {
            assert!(
                matches!(e, Error::Format { kind: "wire", .. }),
                "soup error kind: {e}"
            );
        }
    });
}

/// Satellite invariant (PR 10): the binary codec round-trips every
/// message bit-identically — and agrees with the JSON codec, which
/// round-trips the same value (the two framings are interchangeable
/// encodings of one `Message`, so a fleet can mix them per worker).
#[test]
fn prop_binary_frames_roundtrip_and_agree_with_json() {
    forall("wire-binary-roundtrip", |rng| {
        let msg = random_message(rng);
        let bytes = msg.encode_binary();
        let back = Message::decode_binary(&bytes)
            .unwrap_or_else(|e| panic!("binary decode failed: {e}"));
        assert_eq!(back, msg, "binary trip changed the message");
        let via_json = Message::decode(&msg.encode()).unwrap();
        assert_eq!(via_json, back, "framings disagree");
    });
}

/// Batch frames survive both framings at every size that matters:
/// empty (a flush that raced to nothing), singleton, and many.
#[test]
fn prop_batch_frames_roundtrip_any_size() {
    forall("wire-batch-sizes", |rng| {
        for n in [0, 1, rng.range(2, 40)] {
            let assigns = Message::AssignBatch {
                tasks: (0..n).map(|_| random_assign(rng)).collect(),
            };
            let dones = Message::CompleteBatch {
                done: (0..n)
                    .map(|_| TaskComplete {
                        job: rng.next_below(1 << 40),
                        task_idx: rng.range(0, 100_000),
                        outcome: random_outcome(rng),
                    })
                    .collect(),
            };
            for msg in [assigns, dones] {
                assert_eq!(Message::decode(&msg.encode()).unwrap(), msg);
                assert_eq!(
                    Message::decode_binary(&msg.encode_binary()).unwrap(),
                    msg
                );
            }
        }
    });
}

/// Mangled binary payloads — truncated at any byte, or arbitrary
/// garbage — must come back as `Error::Format`, never a panic and
/// never a silently-wrong message.  (The transport layer separately
/// rejects over-long and truncated *length prefixes*; see the unit
/// tests in `scheduler::remote::transport`.)
#[test]
fn prop_malformed_binary_frames_fail_cleanly() {
    forall("wire-binary-malformed", |rng| {
        let bytes = random_message(rng).encode_binary();
        // Truncate mid-payload (dropping at least one byte).
        let cut = rng.range(0, bytes.len() - 1);
        match Message::decode_binary(&bytes[..cut]) {
            Err(Error::Format { kind: "wire", .. }) => {}
            Err(other) => panic!("wrong error kind: {other}"),
            // A prefix that happens to parse must at least not be the
            // original message grown shorter — the length prefix makes
            // this unreachable in practice, but never panic here.
            Ok(m) => panic!("truncated frame decoded as {m:?}"),
        }
        // Garbage bytes: random soup never panics, and only ever fails
        // as a wire-format error.
        let soup: Vec<u8> = (0..rng.range(1, 64))
            .map(|_| rng.next_below(256) as u8)
            .collect();
        if let Err(e) = Message::decode_binary(&soup) {
            assert!(
                matches!(e, Error::Format { kind: "wire", .. }),
                "soup error kind: {e}"
            );
        }
    });
}

/// Satellite invariant (PR 10): raw frames captured from a pre-PR-10
/// peer — registration without a `wire` field, assignments that are
/// single `assign` lines — decode on a current build exactly as the
/// legacy protocol meant them: no capability, frame-per-task.
#[test]
fn prop_pre_pr10_frames_decode_as_legacy() {
    forall("wire-pre-pr10", |rng| {
        let name = format!("w{}", rng.range(0, 1 << 20));
        let slots = rng.range(1, 64);
        let line = format!(
            "{{\"type\":\"register\",\"name\":\"{name}\",\"slots\":{slots},\"version\":{PROTOCOL_VERSION}}}\n",
        );
        match Message::decode(&line).unwrap() {
            Message::Register {
                name: n,
                slots: s,
                wire,
                ..
            } => {
                assert_eq!((n, s), (name.clone(), slots));
                assert_eq!(wire, None, "legacy register grew a capability");
            }
            other => panic!("decoded as {other:?}"),
        }
        let wid = rng.next_below(1 << 40);
        let line =
            format!("{{\"type\":\"registered\",\"worker_id\":{wid}}}\n");
        match Message::decode(&line).unwrap() {
            Message::Registered { worker_id, wire } => {
                assert_eq!(worker_id, wid);
                assert_eq!(wire, None);
            }
            other => panic!("decoded as {other:?}"),
        }
    });
}

// ---------------------------------------------------------------------------
// Journal replay invariants (DESIGN.md §8)
// ---------------------------------------------------------------------------

use llmapreduce::scheduler::journal::{Record, Replay};

/// Generate a structurally valid journal: one job, every task assigned
/// and possibly retried, a random subset completed (some dead-lettered
/// behind a task-failed record), and a terminal job-done exactly when
/// everything completed.
fn random_journal(rng: &mut Rng) -> Vec<Record> {
    let ntasks = rng.range(1, 12);
    let task_ids: Vec<usize> = (1..=ntasks).collect();
    let mut recs = vec![
        Record::Invocation {
            pid: rng.range(1, 99_999) as u32,
            mapper: "wordcount".into(),
            reducer: Some("wordcount-reducer".into()),
            ntasks,
            options: obj(vec![("np", Json::from(ntasks as f64))]),
        },
        Record::JobSubmitted {
            job: 1,
            name: "wordcount".into(),
            ntasks,
            task_ids: task_ids.clone(),
        },
    ];
    let mut done = 0;
    for (idx, &task_id) in task_ids.iter().enumerate() {
        recs.push(Record::TaskAssigned {
            job: 1,
            idx,
            task_id,
            worker: (rng.next_below(2) == 0)
                .then(|| format!("w{}", rng.range(1, 4))),
        });
        for attempt in 1..=rng.range(0, 3) {
            recs.push(Record::TaskRetry {
                job: 1,
                idx,
                task_id,
                attempt,
            });
        }
        match rng.next_below(4) {
            0 => {} // crashed mid-flight: assigned but never finished
            1 => {
                // Errored, then completed as a dead-letter placeholder.
                recs.push(Record::TaskFailed {
                    job: 1,
                    idx,
                    task_id,
                    msg: "exit status 1".into(),
                });
                recs.push(Record::TaskDone {
                    job: 1,
                    idx,
                    task_id,
                    retries: 0,
                    dead_lettered: true,
                    timing: None,
                });
                done += 1;
            }
            _ => {
                recs.push(Record::TaskDone {
                    job: 1,
                    idx,
                    task_id,
                    retries: rng.range(0, 2),
                    dead_lettered: false,
                    timing: (rng.next_below(2) == 1).then(|| {
                        llmapreduce::scheduler::TaskTiming {
                            started_us: rng.next_below(1 << 20),
                            finished_us: rng.next_below(1 << 22),
                            compute_us: rng.next_below(1 << 20),
                            ..Default::default()
                        }
                    }),
                });
                done += 1;
            }
        }
    }
    if done == ntasks {
        recs.push(Record::JobDone { job: 1 });
    }
    recs
}

fn journal_text(recs: &[Record]) -> String {
    recs.iter()
        .map(|r| r.to_json().to_string_compact())
        .collect::<Vec<_>>()
        .join("\n")
}

/// Any prefix of a valid journal — a crash can cut it anywhere on a
/// line boundary — replays to a structurally consistent state, and
/// completions never leave the submitted task-id set.
#[test]
fn prop_journal_prefixes_replay_consistently() {
    forall("journal-prefix", |rng| {
        let recs = random_journal(rng);
        let text = journal_text(&recs);
        let lines: Vec<&str> = text.lines().collect();
        let path = std::path::Path::new("journal.jsonl");
        for cut in 0..=lines.len() {
            let prefix = lines[..cut].join("\n");
            let replay = Replay::from_text(&prefix, path)
                .unwrap_or_else(|e| {
                    panic!("valid prefix of {cut} lines rejected: {e}")
                });
            assert!(
                replay.consistent(),
                "inconsistent replay at prefix {cut}"
            );
            let done = replay.done_task_ids("wordcount");
            assert!(
                replay
                    .dead_lettered_task_ids("wordcount")
                    .is_subset(&done),
                "dead letters outside done at prefix {cut}"
            );
        }
    });
}

// ---------------------------------------------------------------------------
// Telemetry invariants (DESIGN.md §9)
// ---------------------------------------------------------------------------

use std::sync::{Arc, Mutex};

use llmapreduce::telemetry::{
    Event, EventBus, Histogram, Stamped, Subscriber, LATENCY_BOUNDS_SECS,
};

/// Histogram bucket math under random observations: every value lands
/// in exactly one bucket, cumulative counts are monotone and end at the
/// total, sum/count agree with the inputs, and quantile estimates are
/// monotone in `q` and confined to their containing bucket's bounds.
#[test]
fn prop_histogram_bucket_math() {
    forall("histogram", |rng| {
        assert!(Histogram::latency().quantile(0.5).is_none());
        let mut h = Histogram::latency();
        let n = rng.range(1, 300);
        let mut sum = 0.0;
        for _ in 0..n {
            // 0..40s spans below the first bound through past the last
            // finite bound (the +Inf overflow bucket).
            let v = (rng.next_below(40_000_000) as f64) / 1_000_000.0;
            sum += v;
            h.record(v);
        }
        assert_eq!(h.count(), n as u64);
        assert!((h.sum() - sum).abs() <= 1e-6 * sum.max(1.0));
        let cum = h.cumulative();
        assert!(cum.windows(2).all(|w| w[0] <= w[1]), "monotone buckets");
        assert_eq!(*cum.last().unwrap(), h.count());
        assert_eq!(
            h.bucket_counts().iter().sum::<u64>(),
            h.count(),
            "each observation in exactly one bucket"
        );
        let mut prev = 0.0f64;
        for q in [0.0, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99, 1.0] {
            let est = h.quantile(q).unwrap();
            assert!(est >= prev - 1e-12, "quantile monotone in q");
            prev = est;
            // The estimate stays inside the bucket containing rank
            // q*count (the +Inf bucket reports the last finite bound).
            let rank = q * h.count() as f64;
            let i = cum
                .iter()
                .zip(h.bucket_counts())
                .position(|(c, n)| (*c as f64) >= rank && *n > 0)
                .unwrap_or(cum.len() - 1);
            let lo = if i == 0 { 0.0 } else { LATENCY_BOUNDS_SECS[i - 1] };
            let hi = *LATENCY_BOUNDS_SECS
                .get(i)
                .unwrap_or(LATENCY_BOUNDS_SECS.last().unwrap());
            assert!(
                (lo..=hi).contains(&est),
                "q={q}: estimate {est} outside bucket [{lo}, {hi}]"
            );
        }
    });
}

struct Recorder(Mutex<Vec<Stamped>>);

impl Subscriber for Recorder {
    fn on_event(&self, ev: &Stamped) {
        self.0.lock().unwrap().push(ev.clone());
    }
}

/// Bus ordering: events are stamped and fanned out under one lock, so
/// every subscriber observes (a) globally strictly-increasing sequence
/// numbers and (b) each job's events in exactly its emission order —
/// even when many jobs emit concurrently from separate threads.
#[test]
fn prop_event_bus_preserves_per_job_order() {
    forall("bus-order", |rng| {
        let bus = Arc::new(EventBus::new());
        let rec = Arc::new(Recorder(Mutex::new(Vec::new())));
        bus.subscribe(rec.clone());
        let njobs = rng.range(1, 6);
        let ntasks = rng.range(1, 20);
        let emit_job = |job: u64| {
            bus.emit(Event::JobSubmitted {
                job,
                name: format!("j{job}"),
                ntasks,
            });
            for t in 1..=ntasks {
                bus.emit(Event::TaskAssigned {
                    job,
                    task_id: t,
                    worker: None,
                });
                bus.emit(Event::TaskDone {
                    job,
                    task_id: t,
                    worker: None,
                    dispatch_wait: Duration::ZERO,
                    startup: Duration::ZERO,
                    compute: Duration::ZERO,
                    retries: 0,
                    dead_lettered: false,
                    timing: None,
                });
            }
            bus.emit(Event::JobDone { job });
        };
        std::thread::scope(|s| {
            let emit_job = &emit_job;
            for job in 1..=njobs as u64 {
                s.spawn(move || emit_job(job));
            }
        });
        let seen = rec.0.lock().unwrap();
        assert_eq!(seen.len(), njobs * (2 * ntasks + 2));
        assert!(
            seen.windows(2).all(|w| w[0].seq < w[1].seq),
            "sequence numbers observed strictly increasing"
        );
        for job in 1..=njobs as u64 {
            let mine: Vec<&Event> = seen
                .iter()
                .filter(|s| s.event.job() == Some(job))
                .map(|s| &s.event)
                .collect();
            assert!(
                matches!(mine.first(), Some(Event::JobSubmitted { .. })),
                "job {job} starts with its submission"
            );
            assert!(
                matches!(mine.last(), Some(Event::JobDone { .. })),
                "job {job} ends with its completion"
            );
            for (k, pair) in mine[1..mine.len() - 1].chunks(2).enumerate() {
                let t = k + 1;
                assert!(
                    matches!(pair[0],
                        Event::TaskAssigned { task_id, .. } if *task_id == t),
                    "job {job}: transition {k} out of order"
                );
                assert!(
                    matches!(pair[1],
                        Event::TaskDone { task_id, .. } if *task_id == t),
                    "job {job}: completion {k} out of order"
                );
            }
        }
    });
}

/// A torn tail — the fsync'd line a crash cut mid-write — is tolerated
/// exactly when nothing valid follows it; garbage *between* valid
/// records is `Error::Format`, and nothing ever panics.
#[test]
fn prop_journal_garbage_tail_tolerated_mid_file_rejected() {
    forall("journal-tail", |rng| {
        let recs = random_journal(rng);
        let text = journal_text(&recs);
        let full = Replay::from_text(
            &text,
            std::path::Path::new("journal.jsonl"),
        )
        .unwrap();

        // Truncate the last line mid-byte: a real torn write.
        let nchars = text.chars().count();
        let cut = rng.range(nchars.saturating_sub(20), nchars);
        let torn: String = text.chars().take(cut).collect();
        let path = std::path::Path::new("journal.jsonl");
        let replayed = Replay::from_text(&torn, path)
            .expect("torn tail must be tolerated");
        assert!(replayed.consistent());
        assert!(replayed.records <= full.records);

        // The same garbage mid-file (valid records follow) is corruption.
        let glines: Vec<&str> = text.lines().collect();
        if glines.len() >= 3 {
            let mut bad = glines.clone();
            bad[0] = "{\"rec\": truncated garbag";
            match Replay::from_text(&bad.join("\n"), path) {
                Err(Error::Format { kind: "journal", .. }) => {}
                Err(other) => panic!("wrong error kind: {other}"),
                Ok(_) => {
                    panic!("mid-file corruption must not replay")
                }
            }
        }
    });
}
