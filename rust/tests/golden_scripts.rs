//! Golden tests: the generated `.MAPRED.PID` artifacts must match the
//! paper's figures byte for byte where the figures show full content.

use std::fs;
use std::path::PathBuf;
use std::sync::Arc;

use llmapreduce::apps::wordcount::WordCountApp;
use llmapreduce::mapreduce::{plan, run, Apps};
use llmapreduce::options::{AppType, Options, SchedulerKind};
use llmapreduce::scheduler::dialect::{dialect_for, SubmitRequest};
use llmapreduce::scheduler::local::LocalEngine;
use llmapreduce::workdir::scan::scan_input;

fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("llmr-golden-{tag}-{}", std::process::id()));
    let _ = fs::remove_dir_all(&d);
    fs::create_dir_all(&d).unwrap();
    d
}

/// Fig 8, transliterated: a 6-image job named MatlabCmd.sh under
/// .MAPRED.1120 on Grid Engine.
#[test]
fn golden_fig8_gridengine_submission() {
    let d = dialect_for(SchedulerKind::GridEngine);
    let extra: Vec<String> = vec![];
    let script = d.submission_script(&SubmitRequest {
        job_name: "MatlabCmd.sh",
        tasks: 6,
        mapred_dir: ".MAPRED.1120",
        exclusive: false,
        depends_on: None,
        extra_options: &extra,
    });
    let golden = "\
#!/bin/bash
#$ -terse -cwd -V -j y -N MatlabCmd.sh
#$ -l excl=false -t 1-6
#$ -o .MAPRED.1120/llmap.log-$JOB_ID-$TASK_ID
./.MAPRED.1120/run_llmap_$SGE_TASK_ID
";
    assert_eq!(script, golden);
}

/// Fig 9's run-script shape: one wrapper call with input and output.
#[test]
fn golden_fig9_run_script() {
    let s = llmapreduce::workdir::scripts::siso_run_script(
        "MatlabCmd.sh",
        &[(
            PathBuf::from("input/image1.ppm"),
            PathBuf::from("output/image1.ppm.out"),
        )],
    );
    assert_eq!(
        s,
        "#!/bin/bash\nexport PATH=${PATH}:.\nMatlabCmd.sh input/image1.ppm output/image1.ppm.out\n"
    );
}

/// Fig 12's MIMO run-script shape: one wrapper call with the pair list.
#[test]
fn golden_fig12_mimo_run_script() {
    let s = llmapreduce::workdir::scripts::mimo_run_script(
        "MatlabCmdMulti.sh",
        std::path::Path::new("./.MAPRED.2188/input_1"),
    );
    assert_eq!(
        s,
        "#!/bin/bash\nexport PATH=${PATH}:.\nMatlabCmdMulti.sh ./.MAPRED.2188/input_1\n"
    );
}

/// The full .MAPRED directory layout for a kept MIMO job: submit.sh,
/// run_llmap_N, input_N — the exact file set of Figs 8+12.
#[test]
fn golden_mapred_dir_layout_mimo() {
    let root = tmp("layout");
    let input = root.join("input");
    fs::create_dir_all(&input).unwrap();
    for i in 0..6 {
        fs::write(input.join(format!("im{i}.txt")), "x").unwrap();
    }
    let opts = Options::new(&input, root.join("output"), "wordcount")
        .np(2)
        .apptype(AppType::Mimo)
        .keep(true)
        .workdir(&root)
        .pid(2188);
    let apps = Apps {
        mapper: WordCountApp::new(None),
        reducer: None,
    };
    let eng = LocalEngine::new(2);
    let report = run(&opts, &apps, &eng).unwrap();
    let wd = report.mapred_dir.unwrap();
    assert!(wd.ends_with(".MAPRED.2188"));

    let mut names: Vec<String> = fs::read_dir(&wd)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().to_string())
        .collect();
    names.sort();
    assert_eq!(
        names,
        vec![
            "input_1",
            "input_2",
            "run_llmap_1",
            "run_llmap_2",
            "submit.sh"
        ]
    );
    // input_N pair lists cover all six files, three per task (block).
    for t in 1..=2 {
        let body = fs::read_to_string(wd.join(format!("input_{t}"))).unwrap();
        assert_eq!(body.lines().count(), 3);
        for line in body.lines() {
            let (i, o) = line.split_once(' ').unwrap();
            assert!(i.ends_with(".txt"), "{i}");
            assert!(o.ends_with(".txt.out"), "{o}");
        }
    }
    fs::remove_dir_all(wd).unwrap();
}

/// The same plan lowers to all three dialects — the scheduler-neutral API
/// claim — and each script references its own task-id variable.
#[test]
fn golden_same_plan_all_dialects() {
    let root = tmp("dialects");
    let input = root.join("input");
    fs::create_dir_all(&input).unwrap();
    for i in 0..4 {
        fs::write(input.join(format!("f{i}.dat")), "x").unwrap();
    }
    let files = scan_input(&input, false).unwrap();
    for (kind, idvar) in [
        (SchedulerKind::GridEngine, "$SGE_TASK_ID"),
        (SchedulerKind::Slurm, "$SLURM_ARRAY_TASK_ID"),
        (SchedulerKind::Lsf, "$LSB_JOBINDEX"),
    ] {
        let d = dialect_for(kind);
        let opts = Options::new(&input, root.join("out"), "mapper.sh")
            .np(2)
            .scheduler(kind);
        let p = plan(&files, &opts, d.as_ref()).unwrap();
        assert_eq!(p.tasks.len(), 2, "{kind:?}");
        let extra: Vec<String> = vec![];
        let script = d.submission_script(&SubmitRequest {
            job_name: "mapper.sh",
            tasks: p.tasks.len(),
            mapred_dir: ".MAPRED.7",
            exclusive: false,
            depends_on: None,
            extra_options: &extra,
        });
        assert!(script.contains(idvar), "{kind:?}\n{script}");
        assert!(script.starts_with("#!/bin/bash\n"));
    }
}

/// `--options` directives appear verbatim in every dialect (§II: extra
/// memory example).
#[test]
fn golden_options_passthrough_every_dialect() {
    let extra = vec!["-l mem=8G".to_string(), "-q long".to_string()];
    for kind in [
        SchedulerKind::GridEngine,
        SchedulerKind::Slurm,
        SchedulerKind::Lsf,
    ] {
        let d = dialect_for(kind);
        let script = d.submission_script(&SubmitRequest {
            job_name: "j",
            tasks: 1,
            mapred_dir: ".MAPRED.1",
            exclusive: false,
            depends_on: None,
            extra_options: &extra,
        });
        assert!(script.contains("-l mem=8G"), "{kind:?}");
        assert!(script.contains("-q long"), "{kind:?}");
    }
}

/// Arcane but load-bearing: Slurm's tighter array limit rejects DEFAULT
/// mode over 5,000 files while Grid Engine accepts it (§III-A's limit
/// discussion).
#[test]
fn golden_limits_differ_between_dialects() {
    let files: Vec<_> = (0..5000)
        .map(|i| llmapreduce::workdir::scan::InputFile {
            path: format!("/in/{i}").into(),
            relative: format!("{i}").into(),
        })
        .collect();
    let opts = Options::new("/in", "/out", "m");
    let ge = dialect_for(SchedulerKind::GridEngine);
    let slurm = dialect_for(SchedulerKind::Slurm);
    assert!(plan(&files, &opts, ge.as_ref()).is_ok());
    assert!(plan(&files, &opts, slurm.as_ref()).is_err());
    // --np rescues it, exactly as the paper prescribes.
    let rescued = opts.np(256);
    assert!(plan(&files, &rescued, slurm.as_ref()).is_ok());
}

/// Dependent-reducer submission script, byte for byte, per dialect —
/// the Fig 1 step 3 job ("the reduce task will wait until all the
/// mapper tasks are completed by setting a job dependency").
///
/// Audit note (remote-engine PR): the coordinator's job-level ordering
/// contract is *success-gated* — a dependent job starts only after its
/// dependency completes, and a failed dependency cascades
/// (`scheduler::table::JobTable::fail_job`), identically on
/// `--engine=local|sim|remote`.  SLURM's `afterok:` and LSF's `done()`
/// encode exactly that gate.  Grid Engine's `-hold_jid` — the only
/// dependency primitive the paper's Fig 8 stack has — releases on *any*
/// completion, success or failure; on a real GE cluster the failure
/// then surfaces through the reducer seeing missing outputs rather
/// than through the scheduler.  The difference is deliberate and
/// pinned here so a future dialect edit cannot drift silently.
#[test]
fn golden_dependent_reducer_script_per_dialect() {
    let extra: Vec<String> = vec![];
    let req = |_: SchedulerKind| SubmitRequest {
        job_name: "ReduceWordFreqCmd.sh",
        tasks: 1,
        mapred_dir: ".MAPRED.1120",
        exclusive: false,
        depends_on: Some(42),
        extra_options: &extra,
    };

    let ge = dialect_for(SchedulerKind::GridEngine)
        .submission_script(&req(SchedulerKind::GridEngine));
    assert_eq!(
        ge,
        "#!/bin/bash\n\
         #$ -terse -cwd -V -j y -N ReduceWordFreqCmd.sh\n\
         #$ -l excl=false -t 1-1\n\
         #$ -o .MAPRED.1120/llmap.log-$JOB_ID-$TASK_ID\n\
         #$ -hold_jid 42\n\
         ./.MAPRED.1120/run_llmap_$SGE_TASK_ID\n"
    );

    let slurm = dialect_for(SchedulerKind::Slurm)
        .submission_script(&req(SchedulerKind::Slurm));
    assert_eq!(
        slurm,
        "#!/bin/bash\n\
         #SBATCH --job-name=ReduceWordFreqCmd.sh\n\
         #SBATCH --array=1-1\n\
         #SBATCH --output=.MAPRED.1120/llmap.log-%A-%a\n\
         #SBATCH --dependency=afterok:42\n\
         ./.MAPRED.1120/run_llmap_$SLURM_ARRAY_TASK_ID\n"
    );

    let lsf = dialect_for(SchedulerKind::Lsf)
        .submission_script(&req(SchedulerKind::Lsf));
    assert_eq!(
        lsf,
        "#!/bin/bash\n\
         #BSUB -J \"ReduceWordFreqCmd.sh[1-1]\"\n\
         #BSUB -o .MAPRED.1120/llmap.log-%J-%I\n\
         #BSUB -w \"done(42)\"\n\
         ./.MAPRED.1120/run_llmap_$LSB_JOBINDEX\n"
    );
}

#[test]
fn golden_reduce_script_contract() {
    let s = llmapreduce::workdir::scripts::reduce_run_script(
        "ReduceWordFreqCmd.sh",
        std::path::Path::new("output"),
        std::path::Path::new("output/llmapreduce.out"),
    );
    assert_eq!(
        s,
        "#!/bin/bash\nexport PATH=${PATH}:.\nReduceWordFreqCmd.sh output output/llmapreduce.out\n"
    );
}

// Suppress unused warning (Arc used in other tests' imports).
#[allow(dead_code)]
fn _keep(_: Arc<()>) {}
