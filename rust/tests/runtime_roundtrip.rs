//! The AOT bridge, end to end: HLO text produced by python/compile/aot.py
//! loads, compiles and executes in Rust with correct numerics.
//!
//! This is the integration point the whole three-layer architecture hangs
//! on (python tests stop at parse; the executing side lives here).
//! Tests no-op silently when `make artifacts` hasn't run.

use llmapreduce::apps::image::{grayscale_ref, Image};
use llmapreduce::runtime::{Manifest, XlaExecutable};
use llmapreduce::util::rng::Rng;

fn manifest() -> Option<Manifest> {
    Manifest::discover().ok()
}

#[test]
fn matmul_pair_against_host_reference() {
    let Some(m) = manifest() else { return };
    let entry = m.entry("matmul_pair").unwrap();
    let exe = XlaExecutable::from_entry(entry).unwrap();
    let n = entry.inputs[0].shape[0];
    let mut rng = Rng::new(101);
    let a: Vec<f32> = (0..n * n).map(|_| rng.next_f32() - 0.5).collect();
    let b: Vec<f32> = (0..n * n).map(|_| rng.next_f32() - 0.5).collect();
    let got = exe.run_f32(&[&a, &b]).unwrap();

    // Host reference (naive triple loop).
    let mut expect = vec![0f32; n * n];
    for i in 0..n {
        for k in 0..n {
            let av = a[i * n + k];
            for j in 0..n {
                expect[i * n + j] += av * b[k * n + j];
            }
        }
    }
    let mut max_err = 0f32;
    for (g, e) in got.iter().zip(&expect) {
        max_err = max_err.max((g - e).abs());
    }
    assert!(max_err < 1e-3, "max |err| = {max_err}");
}

#[test]
fn matmul_chain_associativity() {
    // chain(I, A, I, B) == A @ B: exercises the full static chain.
    let Some(m) = manifest() else { return };
    let entry = m.entry("matmul_chain").unwrap();
    let exe = XlaExecutable::from_entry(entry).unwrap();
    let l = entry.inputs[0].shape[0];
    let n = entry.inputs[0].shape[1];
    assert!(l >= 2);

    let mut rng = Rng::new(33);
    let rand_mat =
        |rng: &mut Rng| -> Vec<f32> {
            (0..n * n).map(|_| (rng.next_f32() - 0.5) * 0.2).collect()
        };
    let eye: Vec<f32> = (0..n * n)
        .map(|i| if i % (n + 1) == 0 { 1.0 } else { 0.0 })
        .collect();

    // Stack: [A, B, I, I, ...] -> product A@B.
    let a = rand_mat(&mut rng);
    let b = rand_mat(&mut rng);
    let mut stacked = Vec::with_capacity(l * n * n);
    stacked.extend(&a);
    stacked.extend(&b);
    for _ in 2..l {
        stacked.extend(&eye);
    }
    let got = exe.run_f32(&[&stacked]).unwrap();

    let pair = m.entry("matmul_pair").unwrap();
    let pair_exe = XlaExecutable::from_entry(pair).unwrap();
    let expect = pair_exe.run_f32(&[&a, &b]).unwrap();
    for (g, e) in got.iter().zip(&expect) {
        assert!((g - e).abs() < 1e-3, "{g} vs {e}");
    }
}

#[test]
fn image_convert_matches_host_bt601() {
    let Some(m) = manifest() else { return };
    let entry = m.entry("image_convert").unwrap();
    let exe = XlaExecutable::from_entry(entry).unwrap();
    let h = entry.inputs[0].shape[0];
    let w = entry.inputs[0].shape[1];
    let mut rng = Rng::new(55);
    let rgb: Vec<f32> = (0..h * w * 3).map(|_| rng.next_f32()).collect();
    let got = exe.run_f32(&[&rgb]).unwrap();
    let expect = grayscale_ref(&Image {
        width: w,
        height: h,
        rgb: rgb.clone(),
    });
    for (g, e) in got.iter().zip(&expect) {
        assert!((g - e).abs() < 1e-5, "{g} vs {e}");
    }
}

#[test]
fn frobenius_reduce_artifact() {
    let Some(m) = manifest() else { return };
    let entry = m.entry("frobenius_reduce").unwrap();
    let exe = XlaExecutable::from_entry(entry).unwrap();
    let b = entry.inputs[0].shape[0];
    let n = entry.inputs[0].shape[1];
    // Diagonal matrices with known Frobenius norms: matrix k = k+1 on the
    // diagonal -> norm (k+1)*sqrt(n).
    let mut stack = vec![0f32; b * n * n];
    for k in 0..b {
        for i in 0..n {
            stack[k * n * n + i * n + i] = (k + 1) as f32;
        }
    }
    let got = exe.run_f32(&[&stack]).unwrap();
    assert_eq!(got.len(), 1);
    let expect: f32 =
        (1..=b).map(|k| k as f32 * (n as f32).sqrt()).sum();
    assert!(
        (got[0] - expect).abs() / expect < 1e-5,
        "{} vs {expect}",
        got[0]
    );
}

#[test]
fn compile_time_is_the_startup_cost() {
    // The paper's premise: application start-up (here: XLA compile) is
    // large relative to one file of work.  Verify the ratio exceeds 5x —
    // if this ever fails the MIMO experiments stop being meaningful.
    let Some(m) = manifest() else { return };
    let entry = m.entry("matmul_pair").unwrap();
    let exe = XlaExecutable::from_entry(entry).unwrap();
    let n = entry.inputs[0].shape[0];
    let a = vec![0.1f32; n * n];
    let b = vec![0.2f32; n * n];
    // Warm up once, then time one execute.
    exe.run_f32(&[&a, &b]).unwrap();
    let t = std::time::Instant::now();
    exe.run_f32(&[&a, &b]).unwrap();
    let exec_time = t.elapsed();
    assert!(
        exe.compile_time() > exec_time * 5,
        "compile {:?} should dominate execute {:?}",
        exe.compile_time(),
        exec_time
    );
}

#[test]
fn every_manifest_entry_compiles() {
    let Some(m) = manifest() else { return };
    for entry in &m.entries {
        let exe = XlaExecutable::from_entry(entry)
            .unwrap_or_else(|e| panic!("{}: {e}", entry.name));
        assert_eq!(exe.input_specs().len(), entry.inputs.len());
    }
}
