//! Cross-module integration: full LLMapReduce pipelines over real apps on
//! both engines, engine-equivalence, failure propagation, and the use
//! cases of §III end to end.

use std::fs;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use llmapreduce::apps::matmul::read_result_frobenius;
use llmapreduce::apps::wordcount::read_counts;
use llmapreduce::bench::experiments::block_vs_mimo;
use llmapreduce::prelude::*;
use llmapreduce::scheduler::sim::{ClusterConfig, SimEngine};
use llmapreduce::workload::images::generate_images;
use llmapreduce::workload::matrices::generate_matrix_lists;
use llmapreduce::workload::text::generate_corpus;

fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("llmr-int-{tag}-{}", std::process::id()));
    let _ = fs::remove_dir_all(&d);
    fs::create_dir_all(&d).unwrap();
    d
}

// ---------------------------------------------------------------------------
// §III-B: the word-count use case end to end (no artifacts needed)
// ---------------------------------------------------------------------------

#[test]
fn wordcount_fig15_pipeline_counts_are_exact() {
    let root = tmp("wc-exact");
    let input = root.join("input");
    let (docs, ignore) = generate_corpus(&input, 9, 300, 40, 5).unwrap();

    // Ground truth: count everything by hand.
    let mut expect = std::collections::BTreeMap::new();
    let stop: std::collections::HashSet<String> =
        fs::read_to_string(&ignore)
            .unwrap()
            .split_whitespace()
            .map(|s| s.to_string())
            .collect();
    for doc in &docs {
        for w in fs::read_to_string(doc)
            .unwrap()
            .split(|c: char| !c.is_alphanumeric())
            .filter(|w| !w.is_empty())
        {
            let w = w.to_lowercase();
            if !stop.contains(&w) {
                *expect.entry(w).or_insert(0u64) += 1;
            }
        }
    }

    let opts = Options::new(&input, root.join("output"), "wordcount")
        .np(3)
        .distribution(Distribution::Cyclic)
        .reducer("wordcount-reducer")
        .pid(60001);
    let apps = Apps {
        mapper: WordCountApp::new(Some(ignore)),
        reducer: Some(Arc::new(WordCountReducer)),
    };
    let eng = LocalEngine::new(3);
    let report = llmapreduce::mapreduce::run(&opts, &apps, &eng).unwrap();
    let merged = read_counts(&report.redout_path.unwrap()).unwrap();
    assert_eq!(merged, expect, "map-reduce == sequential ground truth");
}

#[test]
fn wordcount_mimo_and_siso_agree() {
    let root = tmp("wc-agree");
    let input = root.join("input");
    let (_d, ignore) = generate_corpus(&input, 7, 200, 30, 8).unwrap();
    let mk = |apptype, outdir: &str, pid| {
        Options::new(&input, root.join(outdir), "wordcount")
            .np(2)
            .apptype(apptype)
            .reducer("wordcount-reducer")
            .pid(pid)
    };
    let apps = Apps {
        mapper: WordCountApp::new(Some(ignore)),
        reducer: Some(Arc::new(WordCountReducer)),
    };
    let eng = LocalEngine::new(2);
    let siso = llmapreduce::mapreduce::run(
        &mk(AppType::Siso, "out-siso", 60002),
        &apps,
        &eng,
    )
    .unwrap();
    let mimo = llmapreduce::mapreduce::run(
        &mk(AppType::Mimo, "out-mimo", 60003),
        &apps,
        &eng,
    )
    .unwrap();
    let a = read_counts(&siso.redout_path.unwrap()).unwrap();
    let b = read_counts(&mimo.redout_path.unwrap()).unwrap();
    assert_eq!(a, b, "launch protocol must not change results");
    assert!(mimo.map.total_launches() < siso.map.total_launches());
}

// ---------------------------------------------------------------------------
// Engine equivalence: local and executing-sim produce identical outputs
// ---------------------------------------------------------------------------

#[test]
fn local_and_sim_engines_produce_identical_results() {
    let root = tmp("equiv");
    let input = root.join("input");
    let (_d, ignore) = generate_corpus(&input, 6, 150, 20, 9).unwrap();
    let apps = Apps {
        mapper: WordCountApp::new(Some(ignore)),
        reducer: Some(Arc::new(WordCountReducer)),
    };
    let run_on = |engine: &dyn Engine, outdir: &str, pid| {
        let opts = Options::new(&input, root.join(outdir), "wordcount")
            .np(2)
            .reducer("wordcount-reducer")
            .pid(pid);
        llmapreduce::mapreduce::run(&opts, &apps, engine).unwrap()
    };
    let local = LocalEngine::new(2);
    let r1 = run_on(&local, "out-local", 60004);
    let sim =
        SimEngine::new(ClusterConfig::with_width(2)).execute_payloads(true);
    let r2 = run_on(&sim, "out-sim", 60005);
    assert_eq!(
        fs::read_to_string(r1.redout_path.unwrap()).unwrap(),
        fs::read_to_string(r2.redout_path.unwrap()).unwrap(),
    );
}

// ---------------------------------------------------------------------------
// §III-A / §IV with real artifacts (skipped when absent)
// ---------------------------------------------------------------------------

#[test]
fn image_pipeline_full_stack() {
    let Ok(manifest) = Manifest::discover() else { return };
    let mapper = ImageConvertApp::new(&manifest).unwrap();
    let (h, w) = mapper.image_shape();
    let root = tmp("img-stack");
    let input = root.join("input");
    generate_images(&input, 4, h, w, 77).unwrap();

    let opts = Options::new(&input, root.join("output"), "imageconvert")
        .np(2)
        .ext("gray")
        .pid(60006);
    let apps = Apps {
        mapper,
        reducer: None,
    };
    let eng = LocalEngine::new(2);
    let report = llmapreduce::mapreduce::run(&opts, &apps, &eng).unwrap();
    assert_eq!(report.map.total_items(), 4);
    for i in 0..4 {
        let out = root.join(format!("output/im_{i:04}.ppm.gray"));
        let (ow, oh, gray) =
            llmapreduce::apps::image::read_pgm(&out).unwrap();
        assert_eq!((ow, oh), (w, h));
        assert!(gray.iter().all(|v| (0.0..=1.0).contains(v)));
    }
}

#[test]
fn matmul_pipeline_block_vs_mimo_speedup_positive() {
    let Ok(manifest) = Manifest::discover() else { return };
    let mapper = MatmulChainApp::new(&manifest).unwrap();
    let (l, n) = mapper.static_shape();
    let root = tmp("mat-speedup");
    let input = root.join("input");
    generate_matrix_lists(&input, 8, l, n, 13).unwrap();

    let opts = Options::new(&input, root.join("output"), "matmulchain")
        .np(2)
        .reducer("frobsum-reducer")
        .pid(60007);
    let apps = Apps {
        mapper,
        reducer: Some(Arc::new(FrobeniusSumReducer)),
    };
    let eng = LocalEngine::new(2);
    let result =
        block_vs_mimo("matmul", &opts, &apps, &eng).unwrap();
    // 4 files/task with compile-dominated startup: MIMO must win clearly.
    assert!(
        result.speedup() > 1.5,
        "MIMO speed-up {:.2} should exceed 1.5x",
        result.speedup()
    );
    // And the reduce output parses.
    let red = root.join("output/llmapreduce.out");
    let text = fs::read_to_string(&red).unwrap();
    assert!(text.contains("FILES 8"), "{text}");
}

#[test]
fn matmul_outputs_match_frobenius_reference() {
    let Ok(manifest) = Manifest::discover() else { return };
    let mapper = MatmulChainApp::new(&manifest).unwrap();
    let (l, n) = mapper.static_shape();
    let root = tmp("mat-ref");
    let input = root.join("input");
    let paths = generate_matrix_lists(&input, 3, l, n, 21).unwrap();

    let opts = Options::new(&input, root.join("output"), "matmulchain")
        .pid(60008);
    let apps = Apps {
        mapper,
        reducer: None,
    };
    let eng = LocalEngine::new(1);
    llmapreduce::mapreduce::run(&opts, &apps, &eng).unwrap();

    for p in &paths {
        let list =
            llmapreduce::apps::matmul::read_matrix_list(p).unwrap();
        let expect = llmapreduce::apps::matmul::frobenius(
            &llmapreduce::apps::matmul::chain_product_ref(&list),
        );
        let name = p.file_name().unwrap().to_str().unwrap();
        let out = root.join(format!("output/{name}.out"));
        let got = read_result_frobenius(&out).unwrap();
        assert!(
            (got - expect).abs() / expect.max(1e-6) < 1e-3,
            "{name}: {got} vs {expect}"
        );
    }
}

// ---------------------------------------------------------------------------
// Failure injection through the whole stack
// ---------------------------------------------------------------------------

#[test]
fn sim_failure_injection_retries_through_pipeline() {
    let tasks: Vec<llmapreduce::scheduler::TaskSpec> = (0..64)
        .map(|i| llmapreduce::scheduler::TaskSpec {
            task_id: i + 1,
            work: llmapreduce::scheduler::TaskWork::Synthetic {
                startup: Duration::from_millis(1),
                per_item: Duration::from_millis(1),
                items: 2,
                launches: 2,
            },
        })
        .collect();
    let eng = SimEngine::new(ClusterConfig {
        failure_rate: 0.2,
        max_retries: 8,
        seed: 1234,
        ..ClusterConfig::with_width(8)
    });
    let report = eng
        .run(llmapreduce::scheduler::JobSpec::new("flaky", tasks))
        .unwrap();
    assert_eq!(report.tasks.len(), 64);
    assert!(report.tasks.iter().any(|t| t.retries > 0));
    // Retried tasks still did their work.
    assert_eq!(report.total_items(), 128);
}

#[test]
fn app_failure_fails_job_on_both_engines() {
    struct FailingApp;
    struct FailingInstance;
    impl llmapreduce::apps::MapApp for FailingApp {
        fn name(&self) -> &str {
            "failing"
        }
        fn startup(
            &self,
        ) -> llmapreduce::Result<Box<dyn llmapreduce::apps::MapInstance>>
        {
            Ok(Box::new(FailingInstance))
        }
    }
    impl llmapreduce::apps::MapInstance for FailingInstance {
        fn process(
            &mut self,
            input: &std::path::Path,
            _output: &std::path::Path,
        ) -> llmapreduce::Result<()> {
            Err(llmapreduce::Error::App {
                app: "failing".into(),
                input: input.to_path_buf(),
                reason: "always fails".into(),
            })
        }
    }

    let root = tmp("fail-both");
    let input = root.join("input");
    fs::create_dir_all(&input).unwrap();
    fs::write(input.join("x.dat"), "x").unwrap();
    let opts = Options::new(&input, root.join("out"), "failing").pid(60009);
    let apps = Apps {
        mapper: Arc::new(FailingApp),
        reducer: None,
    };
    let local = LocalEngine::new(1);
    assert!(llmapreduce::mapreduce::run(&opts, &apps, &local).is_err());
    let sim =
        SimEngine::new(ClusterConfig::with_width(1)).execute_payloads(true);
    assert!(llmapreduce::mapreduce::run(&opts, &apps, &sim).is_err());
}

// ---------------------------------------------------------------------------
// CLI binary smoke tests
// ---------------------------------------------------------------------------

fn cli() -> Option<PathBuf> {
    // target/<profile>/llmapreduce next to the test binary.
    let exe = std::env::current_exe().ok()?;
    let dir = exe.parent()?.parent()?;
    let bin = dir.join("llmapreduce");
    bin.is_file().then_some(bin)
}

#[test]
fn cli_help_and_gen_data_and_run() {
    let Some(bin) = cli() else { return };
    let root = tmp("cli");

    let help = std::process::Command::new(&bin).output().unwrap();
    assert!(String::from_utf8_lossy(&help.stdout).contains("USAGE"));

    let gen = std::process::Command::new(&bin)
        .args([
            "gen-data",
            "corpus",
            &format!("--dir={}", root.join("input").display()),
            "--count=5",
        ])
        .output()
        .unwrap();
    assert!(gen.status.success(), "{:?}", gen);

    let run = std::process::Command::new(&bin)
        .current_dir(&root)
        .args([
            "run",
            "--mapper=wordcount",
            &format!("--input={}", root.join("input").display()),
            &format!("--output={}", root.join("output").display()),
            "--np=2",
            "--reducer=wordcount-reducer",
            "--apptype=mimo",
        ])
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&run.stdout);
    assert!(run.status.success(), "stdout={stdout} stderr={}",
        String::from_utf8_lossy(&run.stderr));
    assert!(stdout.contains("5 files"), "{stdout}");
    assert!(root.join("output/llmapreduce.out").is_file());
}

#[test]
fn cli_rejects_bad_options() {
    let Some(bin) = cli() else { return };
    let out = std::process::Command::new(&bin)
        .args(["run", "--mapper=wordcount", "--np=0"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("error"));
}

// ---------------------------------------------------------------------------
// Additional coverage: list-file inputs, exclusive allocation, engines,
// config-file defaults
// ---------------------------------------------------------------------------

#[test]
fn list_file_input_through_pipeline() {
    // §II: input can be "a list from a given input file" instead of a dir.
    let root = tmp("listfile");
    let data = root.join("data");
    fs::create_dir_all(&data).unwrap();
    for i in 0..4 {
        fs::write(data.join(format!("d{i}.txt")), format!("word{i}")).unwrap();
    }
    let list = root.join("inputs.list");
    fs::write(
        &list,
        "# chosen subset, not the whole directory\nd0.txt\nd2.txt\n",
    )
    .unwrap();
    // Relative entries resolve against the list file's directory — put
    // the list next to the data.
    let list = data.join("inputs.list");
    fs::write(&list, "# subset\nd0.txt\nd2.txt\n").unwrap();
    let opts = Options::new(&list, root.join("out"), "wordcount").pid(60010);
    let apps = Apps {
        mapper: WordCountApp::new(None),
        reducer: None,
    };
    let eng = LocalEngine::new(1);
    let report = llmapreduce::mapreduce::run(&opts, &apps, &eng).unwrap();
    assert_eq!(report.map.total_items(), 2, "only the listed files");
    assert!(root.join("out/d0.txt.out").is_file());
    assert!(!root.join("out/d1.txt.out").exists());
}

#[test]
fn exclusive_option_flows_to_sim_allocation() {
    use llmapreduce::scheduler::{JobSpec, TaskSpec, TaskWork};
    // 2 nodes x 2 slots; 4 exclusive 10ms tasks must take 2 waves.
    let mk_tasks = || -> Vec<TaskSpec> {
        (0..4)
            .map(|i| TaskSpec {
                task_id: i + 1,
                work: TaskWork::Synthetic {
                    startup: Duration::ZERO,
                    per_item: Duration::from_millis(10),
                    items: 1,
                    launches: 1,
                },
            })
            .collect()
    };
    let cfg = ClusterConfig {
        nodes: 2,
        slots_per_node: 2,
        dispatch_latency: Duration::ZERO,
        ..Default::default()
    };
    let excl = SimEngine::new(cfg.clone())
        .run(JobSpec::new("e", mk_tasks()).exclusive(true))
        .unwrap();
    let shared = SimEngine::new(cfg)
        .run(JobSpec::new("s", mk_tasks()))
        .unwrap();
    assert!(excl.makespan >= Duration::from_millis(20));
    assert!(shared.makespan < Duration::from_millis(20));
    // Utilization reflects the wasted exclusive slots.
    assert!(excl.utilization() < shared.utilization());
}

#[test]
fn cli_engine_sim_exec_runs_pipeline() {
    let Some(bin) = cli() else { return };
    let root = tmp("cli-sim");
    let gen = std::process::Command::new(&bin)
        .args([
            "gen-data",
            "corpus",
            &format!("--dir={}", root.join("input").display()),
            "--count=4",
        ])
        .output()
        .unwrap();
    assert!(gen.status.success());
    let run = std::process::Command::new(&bin)
        .current_dir(&root)
        .args([
            "run",
            "--mapper=wordcount",
            &format!("--input={}", root.join("input").display()),
            &format!("--output={}", root.join("output").display()),
            "--np=2",
            "--apptype=mimo",
            "--engine=sim-exec",
        ])
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&run.stdout);
    assert!(run.status.success(), "{stdout} {}", String::from_utf8_lossy(&run.stderr));
    assert!(stdout.contains("engine: sim"), "{stdout}");
    // Real outputs despite the virtual clock.
    assert!(root.join("output/doc_0000.txt.out").is_file());
}

#[test]
fn config_file_defaults_apply_to_cli() {
    let Some(bin) = cli() else { return };
    let root = tmp("cli-config");
    fs::write(
        root.join("llmapreduce.toml"),
        "[job]\nnp = 2\napptype = \"mimo\"\n",
    )
    .unwrap();
    let gen = std::process::Command::new(&bin)
        .args([
            "gen-data",
            "corpus",
            &format!("--dir={}", root.join("input").display()),
            "--count=6",
        ])
        .output()
        .unwrap();
    assert!(gen.status.success());
    let run = std::process::Command::new(&bin)
        .current_dir(&root)
        .args([
            "run",
            "--mapper=wordcount",
            &format!("--input={}", root.join("input").display()),
            &format!("--output={}", root.join("output").display()),
        ])
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&run.stdout);
    assert!(run.status.success(), "{stdout}");
    // np=2 from config -> 2 tasks; mimo -> 2 launches over 6 files.
    assert!(stdout.contains("6 files, 2 tasks, 2 launches"), "{stdout}");
}

#[test]
fn image_pipeline_app_through_pipeline() {
    let Ok(manifest) = Manifest::discover() else { return };
    let Ok(mapper) =
        llmapreduce::apps::image::ImageConvertApp::pipeline(&manifest)
    else {
        return;
    };
    let (h, w) = mapper.image_shape();
    let root = tmp("imgpipe");
    let input = root.join("input");
    generate_images(&input, 2, h, w, 3).unwrap();
    let opts = Options::new(&input, root.join("output"), "imagepipeline")
        .apptype(AppType::Mimo)
        .pid(60011);
    let apps = Apps {
        mapper,
        reducer: None,
    };
    let eng = LocalEngine::new(1);
    let report = llmapreduce::mapreduce::run(&opts, &apps, &eng).unwrap();
    assert_eq!(report.map.total_items(), 2);
    let (ow, oh, gray) = llmapreduce::apps::image::read_pgm(
        &root.join("output/im_0000.ppm.out"),
    )
    .unwrap();
    assert_eq!((ow, oh), (w, h));
    assert!(gray.iter().all(|v| (0.0..=1.0).contains(v)));
}

// ---------------------------------------------------------------------------
// Simulator validation: the DES calibrated from the real app must predict
// real elapsed times at the widths this container can actually run
// (np = 1: the only width where 1 core gives honest parallel semantics).
// This is the load-bearing check for the DESIGN.md §3 substitution.
// ---------------------------------------------------------------------------

#[test]
fn calibrated_sim_predicts_real_elapsed_within_40_percent() {
    use llmapreduce::scheduler::cost::Calibration;
    use llmapreduce::scheduler::{JobSpec, TaskSpec, TaskWork};

    let Ok(manifest) = Manifest::discover() else { return };
    let mapper = MatmulChainApp::new(&manifest).unwrap();
    let (l, n) = mapper.static_shape();
    let root = tmp("sim-validate");
    let input = root.join("input");
    let nfiles = 10;
    let paths = generate_matrix_lists(&input, nfiles, l, n, 17).unwrap();

    // Calibrate from a held-out sample (outputs OUTSIDE the input dir so
    // the later scan doesn't pick them up as data).
    let calib_dir = root.join("calib");
    fs::create_dir_all(&calib_dir).unwrap();
    let sample: Vec<_> = paths
        .iter()
        .take(3)
        .map(|p| {
            (
                p.clone(),
                calib_dir.join(p.file_name().unwrap()).with_extension("out"),
            )
        })
        .collect();
    let cal = Calibration::measure(mapper.as_ref(), &sample, 2).unwrap();

    // Real run: np=1, MIMO over all files.
    let opts = Options::new(&input, root.join("output"), "matmulchain")
        .np(1)
        .apptype(AppType::Mimo)
        .pid(60012);
    let apps = Apps {
        mapper: mapper.clone(),
        reducer: None,
    };
    let local = LocalEngine::new(1);
    let real = llmapreduce::mapreduce::run(&opts, &apps, &local)
        .unwrap()
        .map
        .makespan;

    // Simulated prediction from the calibrated costs.
    let sim = SimEngine::new(ClusterConfig {
        dispatch_latency: Duration::ZERO,
        ..ClusterConfig::with_width(1)
    });
    let predicted = sim
        .run(JobSpec::new(
            "predict",
            vec![TaskSpec {
                task_id: 1,
                work: TaskWork::Synthetic {
                    startup: cal.hint.startup,
                    per_item: cal.hint.per_item,
                    items: nfiles,
                    launches: 1,
                },
            }],
        ))
        .unwrap()
        .makespan;

    let err = (real.as_secs_f64() - predicted.as_secs_f64()).abs()
        / real.as_secs_f64();
    println!(
        "sim validation: predicted {predicted:?} vs real {real:?} ({:.0}% error)",
        err * 100.0
    );
    assert!(
        err < 0.4,
        "sim predicted {predicted:?} vs real {real:?} ({:.0}% off) — \
         calibration drift breaks the Fig 18/19 substitution",
        err * 100.0
    );
}
