//! Integration tests for the background-dispatch local engine and the
//! overlapped map→reduce path, through public API only.

use std::fs;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use llmapreduce::apps::wordcount::{WordCountApp, WordCountReducer};
use llmapreduce::mapreduce::{run, Apps};
use llmapreduce::options::Options;
use llmapreduce::prelude::{
    ClusterConfig, Engine, FailurePolicy, LocalEngine, SimEngine,
};
use llmapreduce::scheduler::{JobId, JobSpec, TaskSpec, TaskWork};

fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("llmr-dispatch-{tag}-{}", std::process::id()));
    let _ = fs::remove_dir_all(&d);
    fs::create_dir_all(&d).unwrap();
    d
}

fn synth_tasks(n: usize, startup_ms: u64) -> Vec<TaskSpec> {
    (0..n)
        .map(|i| TaskSpec {
            task_id: i + 1,
            work: TaskWork::Synthetic {
                startup: Duration::from_millis(startup_ms),
                per_item: Duration::ZERO,
                items: 0,
                launches: 1,
            },
        })
        .collect()
}

#[test]
fn submit_returns_before_execution() {
    let eng = LocalEngine::new(1);
    let t0 = Instant::now();
    let id = eng
        .submit(JobSpec::new("slow", synth_tasks(1, 150)))
        .unwrap();
    let submit_latency = t0.elapsed();
    assert!(
        submit_latency < Duration::from_millis(100),
        "submit() must hand the job to the dispatcher and return, not \
         execute it inline (took {submit_latency:?})"
    );
    let report = eng.wait(id).unwrap();
    assert!(
        report.makespan >= Duration::from_millis(140),
        "the 150ms task really ran: {:?}",
        report.makespan
    );
}

#[test]
fn many_independent_jobs_share_the_pool_and_all_finish() {
    let eng = LocalEngine::new(2);
    let ids: Vec<JobId> = (0..5)
        .map(|k| {
            eng.submit(JobSpec::new(format!("job-{k}"), synth_tasks(3, 1)))
                .unwrap()
        })
        .collect();
    // Waited out of submission order, every job completes fully.
    for id in ids.iter().rev() {
        let r = eng.wait(*id).unwrap();
        assert_eq!(r.tasks.len(), 3);
        assert_eq!(r.total_launches(), 3);
    }
}

#[test]
fn task_dep_validation_through_public_api() {
    let eng = LocalEngine::new(1);
    // task_deps without depends_on is rejected.
    let orphan = JobSpec {
        task_deps: vec![(0, 0)],
        ..JobSpec::new("orphan", synth_tasks(1, 1))
    };
    assert!(eng.submit(orphan).is_err());
    // In-range edges are accepted and execute in order.
    let a = eng.submit(JobSpec::new("a", synth_tasks(2, 1))).unwrap();
    let b = eng
        .submit(
            JobSpec::new("b", synth_tasks(2, 1))
                .after_tasks(a, vec![(0, 0), (1, 1)]),
        )
        .unwrap();
    assert_eq!(eng.wait(b).unwrap().tasks.len(), 2);
}

#[test]
fn local_and_sim_agree_on_injected_retry_counts() {
    let (rate, max_retries, seed) = (0.4, 6, 21);
    let local = LocalEngine::with_policy(
        2,
        FailurePolicy {
            failure_rate: rate,
            max_retries,
            seed,
        },
    );
    let lr = local
        .run(JobSpec::new("flaky", synth_tasks(12, 1)))
        .unwrap();
    let sim = SimEngine::new(ClusterConfig {
        failure_rate: rate,
        max_retries,
        seed,
        dispatch_latency: Duration::from_millis(1),
        ..ClusterConfig::with_width(2)
    });
    let sr = sim.run(JobSpec::new("flaky", synth_tasks(12, 1))).unwrap();
    let mut lv: Vec<(usize, usize)> =
        lr.tasks.iter().map(|t| (t.task_id, t.retries)).collect();
    let mut sv: Vec<(usize, usize)> =
        sr.tasks.iter().map(|t| (t.task_id, t.retries)).collect();
    lv.sort_unstable();
    sv.sort_unstable();
    assert_eq!(lv, sv, "one failure-injection contract across engines");
}

#[test]
fn overlapped_wordcount_equals_barriered_result() {
    let root = tmp("wc-overlap");
    let input = root.join("input");
    fs::create_dir_all(&input).unwrap();
    for (i, text) in [
        "the quick brown fox",
        "jumps over the lazy dog",
        "the dog barks",
        "quick quick slow",
        "over and over and over",
        "fox and dog and fox",
    ]
    .iter()
    .enumerate()
    {
        fs::write(input.join(format!("d{i}.txt")), text).unwrap();
    }
    let mut results = Vec::new();
    for overlap in [false, true] {
        let out =
            root.join(if overlap { "out-overlap" } else { "out-barrier" });
        let opts = Options::new(&input, &out, "wordcount")
            .np(3)
            .reducer("wordcount-reducer")
            .overlap(overlap)
            .workdir(&root)
            .pid(70100 + overlap as u32);
        let apps = Apps {
            mapper: WordCountApp::new(None),
            reducer: Some(Arc::new(WordCountReducer)),
        };
        let eng = LocalEngine::new(2);
        let report = run(&opts, &apps, &eng).unwrap();
        assert_eq!(report.overlapped, overlap);
        assert_eq!(report.partials.is_some(), overlap);
        results.push(
            fs::read_to_string(report.redout_path.unwrap()).unwrap(),
        );
    }
    assert_eq!(
        results[0], results[1],
        "overlapped reduce must produce byte-identical word counts"
    );
}
