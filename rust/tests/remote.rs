//! Integration tests of the distributed coordinator/worker engine.
//!
//! Worker daemons are hosted on plain threads via the library entry
//! point (`run_worker`) against a coordinator bound to an ephemeral
//! localhost port — real TCP, real serialization, no mocks.  The
//! acceptance bar throughout: the wordcount pipeline must produce
//! byte-identical output on `LocalEngine` and on a coordinator with
//! several workers, including the `--overlap` and nested-multilevel
//! paths, and losing a worker mid-job must not lose the job.

use std::fs;
use std::path::PathBuf;
use std::thread::JoinHandle;
use std::time::Duration;

use llmapreduce::error::Result;
use llmapreduce::mapreduce::multilevel::run_nested;
use llmapreduce::mapreduce::{run, Apps};
use llmapreduce::options::Options;
use llmapreduce::prelude::{
    run_worker, CoordinatorConfig, Engine, LocalEngine, RemoteCoordinator,
    WorkerConfig,
};
use llmapreduce::scheduler::{JobSpec, TaskSpec, TaskWork};

fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("llmr-remote-{tag}-{}", std::process::id()));
    let _ = fs::remove_dir_all(&d);
    fs::create_dir_all(&d).unwrap();
    d
}

/// Deterministic corpus: overlapping word multisets across files.
fn write_corpus(input: &PathBuf, nfiles: usize) {
    fs::create_dir_all(input).unwrap();
    let vocab = ["alpha", "beta", "gamma", "delta", "epsilon"];
    for i in 0..nfiles {
        let mut text = String::new();
        for (w, word) in vocab.iter().enumerate() {
            for _ in 0..(i + w) % 4 + 1 {
                text.push_str(word);
                text.push(' ');
            }
        }
        fs::write(input.join(format!("doc{i:02}.txt")), text).unwrap();
    }
}

fn bind_coordinator(heartbeat_ms: u64) -> RemoteCoordinator {
    RemoteCoordinator::bind(
        "127.0.0.1:0",
        CoordinatorConfig {
            heartbeat_timeout: Duration::from_millis(heartbeat_ms),
            ..Default::default()
        },
    )
    .unwrap()
}

/// Host `n` single-slot workers on threads against `coordinator`.
fn spawn_workers(
    coordinator: &RemoteCoordinator,
    n: usize,
) -> Vec<JoinHandle<Result<()>>> {
    let addr = coordinator.local_addr().to_string();
    (0..n)
        .map(|i| {
            let config = WorkerConfig::new(addr.clone())
                .name(format!("w{i}"))
                .slots(1);
            std::thread::spawn(move || run_worker(config))
        })
        .collect()
}

fn synth_tasks(n: usize) -> Vec<TaskSpec> {
    (0..n)
        .map(|i| TaskSpec {
            task_id: i + 1,
            work: TaskWork::Synthetic {
                startup: Duration::from_micros(200),
                per_item: Duration::from_micros(100),
                items: 2,
                launches: 1,
            },
        })
        .collect()
}

fn wordcount_opts(input: &PathBuf, output: &PathBuf, pid: u32) -> Options {
    Options::new(input, output, "wordcount")
        .np(4)
        .reducer("wordcount-reducer")
        .pid(pid)
}

fn wordcount_apps() -> Apps {
    Apps {
        mapper: llmapreduce::apps::registry::resolve_mapper("wordcount")
            .unwrap(),
        reducer: Some(
            llmapreduce::apps::registry::resolve_reducer(
                "wordcount-reducer",
            )
            .unwrap(),
        ),
    }
}

#[test]
fn remote_engine_runs_jobs_with_worker_attribution() {
    let coordinator = bind_coordinator(3000);
    let workers = spawn_workers(&coordinator, 2);
    coordinator
        .wait_for_workers(2, Duration::from_secs(10))
        .unwrap();
    let report = coordinator
        .run(JobSpec::new("synthetic", synth_tasks(6)))
        .unwrap();
    assert_eq!(report.tasks.len(), 6);
    assert_eq!(report.slots, 2, "width = sum of worker slots");
    for t in &report.tasks {
        let w = t.worker.as_deref().expect("remote tasks name a worker");
        assert!(w == "w0" || w == "w1", "{w}");
        assert_eq!(t.reassigned, 0);
    }
    // Both single-slot workers really shared the job.
    let names: std::collections::HashSet<_> = report
        .tasks
        .iter()
        .map(|t| t.worker.clone().unwrap())
        .collect();
    assert_eq!(names.len(), 2, "placement spreads over equal workers");
    drop(coordinator);
    for w in workers {
        w.join().unwrap().unwrap();
    }
}

#[test]
fn wordcount_byte_identical_local_vs_remote() {
    let root = tmp("wc");
    let input = root.join("input");
    write_corpus(&input, 8);

    let local_out = root.join("out-local");
    let eng = LocalEngine::new(2);
    let local = run(
        &wordcount_opts(&input, &local_out, 92001).workdir(&root),
        &wordcount_apps(),
        &eng,
    )
    .unwrap();

    let remote_out = root.join("out-remote");
    let coordinator = bind_coordinator(3000);
    let workers = spawn_workers(&coordinator, 2);
    coordinator
        .wait_for_workers(2, Duration::from_secs(10))
        .unwrap();
    let remote = run(
        &wordcount_opts(&input, &remote_out, 92002).workdir(&root),
        &wordcount_apps(),
        &coordinator,
    )
    .unwrap();

    let local_bytes =
        fs::read(local.redout_path.as_ref().unwrap()).unwrap();
    let remote_bytes =
        fs::read(remote.redout_path.as_ref().unwrap()).unwrap();
    assert!(!local_bytes.is_empty());
    assert_eq!(
        local_bytes, remote_bytes,
        "remote wordcount must be byte-identical to local"
    );
    drop(coordinator);
    for w in workers {
        w.join().unwrap().unwrap();
    }
}

#[test]
fn overlapped_wordcount_byte_identical_local_vs_remote() {
    let root = tmp("overlap");
    let input = root.join("input");
    write_corpus(&input, 8);

    let eng = LocalEngine::new(2);
    let local = run(
        &wordcount_opts(&input, &root.join("out-local"), 92011)
            .overlap(true)
            .workdir(&root),
        &wordcount_apps(),
        &eng,
    )
    .unwrap();
    assert!(local.overlapped, "wordcount reducer supports partials");

    let coordinator = bind_coordinator(3000);
    let workers = spawn_workers(&coordinator, 3);
    coordinator
        .wait_for_workers(3, Duration::from_secs(10))
        .unwrap();
    let remote = run(
        &wordcount_opts(&input, &root.join("out-remote"), 92012)
            .overlap(true)
            .workdir(&root),
        &wordcount_apps(),
        &coordinator,
    )
    .unwrap();
    assert!(remote.overlapped);
    assert_eq!(
        remote.partials.as_ref().unwrap().tasks.len(),
        4,
        "one shipped partial-reduce per mapper task"
    );
    assert_eq!(
        fs::read(local.redout_path.as_ref().unwrap()).unwrap(),
        fs::read(remote.redout_path.as_ref().unwrap()).unwrap(),
        "overlapped remote output must match overlapped local output"
    );
    drop(coordinator);
    for w in workers {
        w.join().unwrap().unwrap();
    }
}

#[test]
fn nested_multilevel_byte_identical_local_vs_remote() {
    let root = tmp("nested");
    let input = root.join("input");
    for b in 0..3 {
        let d = input.join(format!("branch-{b}"));
        write_corpus(&d, 3 + b);
    }

    let mk_opts = |out: &str, pid: u32| {
        Options::new(&input, root.join(out), "wordcount")
            .np(2)
            .reducer("wordcount-reducer")
            .workdir(&root)
            .pid(pid)
    };
    let outer = llmapreduce::apps::registry::resolve_reducer(
        "wordcount-reducer",
    )
    .unwrap();

    let eng = LocalEngine::new(3);
    let local = run_nested(
        &mk_opts("out-local", 92021),
        &wordcount_apps(),
        Some(outer.clone()),
        &eng,
    )
    .unwrap();

    let coordinator = bind_coordinator(3000);
    let workers = spawn_workers(&coordinator, 3);
    coordinator
        .wait_for_workers(3, Duration::from_secs(10))
        .unwrap();
    let remote = run_nested(
        &mk_opts("out-remote", 92022),
        &wordcount_apps(),
        Some(outer),
        &coordinator,
    )
    .unwrap();

    let local_out = local.final_out.expect("outer reducer ran");
    let remote_out = remote.final_out.expect("outer reducer ran");
    assert_eq!(
        fs::read(&local_out).unwrap(),
        fs::read(&remote_out).unwrap(),
        "multilevel fan-out over the network must merge identically"
    );
    drop(coordinator);
    for w in workers {
        w.join().unwrap().unwrap();
    }
}

/// Satellite: kill one of three workers mid-job; the pipeline still
/// completes with correct output and the report shows the reassignment.
/// Deterministic: the doomed worker drops its connection cold upon
/// receiving its first assignment (which it never executes), and with
/// three idle single-slot workers the least-loaded placement guarantees
/// it receives one of the first three tasks.
#[test]
fn killing_a_worker_mid_job_reassigns_its_tasks() {
    let root = tmp("kill");
    let input = root.join("input");
    write_corpus(&input, 12);

    let coordinator = bind_coordinator(3000);
    let addr = coordinator.local_addr().to_string();
    let survivors = spawn_workers(&coordinator, 2); // w0, w1
    let doomed = {
        let config = WorkerConfig::new(addr)
            .name("doomed")
            .slots(1)
            .fail_after(1);
        std::thread::spawn(move || run_worker(config))
    };
    coordinator
        .wait_for_workers(3, Duration::from_secs(10))
        .unwrap();

    let opts = Options::new(&input, root.join("out"), "wordcount")
        .np(6)
        .reducer("wordcount-reducer")
        .workdir(&root)
        .pid(92031);
    let remote = run(&opts, &wordcount_apps(), &coordinator).unwrap();

    // Correctness: identical to a local run of the same options.
    let eng = LocalEngine::new(2);
    let local = run(
        &Options::new(&input, root.join("out-local"), "wordcount")
            .np(6)
            .reducer("wordcount-reducer")
            .workdir(&root)
            .pid(92032),
        &wordcount_apps(),
        &eng,
    )
    .unwrap();
    assert_eq!(
        fs::read(local.redout_path.as_ref().unwrap()).unwrap(),
        fs::read(remote.redout_path.as_ref().unwrap()).unwrap(),
        "output must survive the worker loss unchanged"
    );

    // The report shows the reassignment: the doomed worker completed
    // nothing, and at least one task records its extra trip.
    let reassigned: usize =
        remote.map.tasks.iter().map(|t| t.reassigned).sum();
    assert!(reassigned >= 1, "one task was shipped to the dead worker");
    for t in &remote.map.tasks {
        assert_ne!(
            t.worker.as_deref(),
            Some("doomed"),
            "dead workers complete nothing"
        );
    }

    doomed.join().unwrap().unwrap();
    drop(coordinator);
    for w in survivors {
        w.join().unwrap().unwrap();
    }
}

/// A worker that registers but never heartbeats (a wedged machine, not
/// a dropped connection) is declared dead after the lapse and its task
/// reassigned to a surviving worker.
#[test]
fn heartbeat_lapse_triggers_reassignment() {
    use llmapreduce::scheduler::remote::protocol::{
        Message, PROTOCOL_VERSION,
    };
    use llmapreduce::scheduler::remote::transport::split;

    // Lapse tight enough to keep the test fast, loose enough that the
    // zombie cannot be swept before the job is even submitted.
    let coordinator = bind_coordinator(1000);
    let addr = coordinator.local_addr();

    // Hand-rolled zombie: registers with one slot, then goes silent.
    let stream = std::net::TcpStream::connect(addr).unwrap();
    let (mut reader, mut writer) = split(stream).unwrap();
    writer
        .send(&Message::Register {
            name: "zombie".into(),
            slots: 1,
            version: PROTOCOL_VERSION,
            // Legacy-shaped registration: no wire capability, so the
            // coordinator must keep speaking frame-per-task line JSON.
            wire: None,
        })
        .unwrap();
    assert!(matches!(
        reader.recv().unwrap(),
        Some(Message::Registered { .. })
    ));

    // The real worker beacons well under the lapse so only the zombie
    // gets swept.
    let workers = vec![{
        let mut config = WorkerConfig::new(
            coordinator.local_addr().to_string(),
        )
        .name("w0")
        .slots(1);
        config.heartbeat_interval = Duration::from_millis(50);
        std::thread::spawn(move || run_worker(config))
    }];
    coordinator
        .wait_for_workers(2, Duration::from_secs(10))
        .unwrap();

    // Two tasks: spread gives the zombie one; it never runs it.
    let report = coordinator
        .run(JobSpec::new("lapse", synth_tasks(2)))
        .unwrap();
    assert_eq!(report.tasks.len(), 2);
    let reassigned: usize =
        report.tasks.iter().map(|t| t.reassigned).sum();
    assert!(reassigned >= 1, "zombie's task must be reassigned");
    for t in &report.tasks {
        assert_eq!(t.worker.as_deref(), Some("w0"));
    }
    drop(coordinator);
    for w in workers {
        w.join().unwrap().unwrap();
    }
}

/// Losing the entire fleet must fail the job with a clear error, not
/// hang `wait()` forever on capacity that never returns.
#[test]
fn losing_every_worker_fails_live_jobs_instead_of_hanging() {
    let coordinator = bind_coordinator(3000);
    let addr = coordinator.local_addr().to_string();
    let doomed = {
        let config = WorkerConfig::new(addr)
            .name("only-and-doomed")
            .slots(1)
            .fail_after(1);
        std::thread::spawn(move || run_worker(config))
    };
    coordinator
        .wait_for_workers(1, Duration::from_secs(10))
        .unwrap();
    let err = coordinator
        .run(JobSpec::new("stranded", synth_tasks(3)))
        .unwrap_err()
        .to_string();
    assert!(err.contains("all workers lost"), "{err}");
    doomed.join().unwrap().unwrap();
}

/// `--exclusive` gives a task a whole worker, like the simulator's
/// whole-node charge: a 2-slot worker runs exclusive tasks one at a
/// time.
#[test]
fn exclusive_tasks_occupy_a_whole_worker() {
    let coordinator = bind_coordinator(3000);
    let addr = coordinator.local_addr().to_string();
    let worker = {
        let config =
            WorkerConfig::new(addr).name("wide").slots(2);
        std::thread::spawn(move || run_worker(config))
    };
    coordinator
        .wait_for_workers(1, Duration::from_secs(10))
        .unwrap();
    let report = coordinator
        .run(JobSpec::new("excl", synth_tasks(4)).exclusive(true))
        .unwrap();
    assert_eq!(report.tasks.len(), 4);
    // Whole-worker charge serializes the tasks: no two overlap.
    let mut intervals: Vec<_> = report
        .tasks
        .iter()
        .map(|t| (t.started_at, t.finished_at))
        .collect();
    intervals.sort();
    for w in intervals.windows(2) {
        assert!(
            w[0].1 <= w[1].0 + Duration::from_millis(5),
            "exclusive tasks must not share the worker: {intervals:?}"
        );
    }
    drop(coordinator);
    worker.join().unwrap().unwrap();
}

#[test]
fn unresolvable_app_fails_the_job_cleanly() {
    let root = tmp("unresolvable");
    let input = root.join("input");
    write_corpus(&input, 2);
    let coordinator = bind_coordinator(3000);
    let workers = spawn_workers(&coordinator, 1);
    coordinator
        .wait_for_workers(1, Duration::from_secs(10))
        .unwrap();
    let opts = Options::new(
        &input,
        root.join("out"),
        "definitely-not-a-real-binary-xyz",
    )
    .workdir(&root)
    .pid(92041);
    let err = run(&opts, &wordcount_apps_with_broken_mapper(), &coordinator)
        .unwrap_err()
        .to_string();
    assert!(
        err.contains("definitely-not-a-real-binary-xyz")
            || err.contains("spawn failed"),
        "{err}"
    );
    drop(coordinator);
    for w in workers {
        w.join().unwrap().unwrap();
    }
}

fn wordcount_apps_with_broken_mapper() -> Apps {
    Apps {
        mapper: llmapreduce::apps::registry::resolve_mapper(
            "definitely-not-a-real-binary-xyz",
        )
        .unwrap(),
        reducer: None,
    }
}

/// Process-level smoke: real `llmapreduce worker` subprocesses against
/// an in-process coordinator, one of them dying mid-job via the chaos
/// knob — the closest thing to `kill -9` CI allows deterministically.
#[test]
fn worker_processes_end_to_end_with_one_killed() {
    let bin = env!("CARGO_BIN_EXE_llmapreduce");
    let root = tmp("procs");
    let input = root.join("input");
    write_corpus(&input, 10);

    let coordinator = bind_coordinator(3000);
    let addr = coordinator.local_addr().to_string();
    let mut children = vec![
        std::process::Command::new(bin)
            .args(["worker", &format!("--connect={addr}"), "--name=p0"])
            .spawn()
            .unwrap(),
        std::process::Command::new(bin)
            .args(["worker", &format!("--connect={addr}"), "--name=p1"])
            .spawn()
            .unwrap(),
        std::process::Command::new(bin)
            .args([
                "worker",
                &format!("--connect={addr}"),
                "--name=p-doomed",
                "--fail-after=1",
            ])
            .spawn()
            .unwrap(),
    ];
    coordinator
        .wait_for_workers(3, Duration::from_secs(30))
        .unwrap();

    let opts = Options::new(&input, root.join("out"), "wordcount")
        .np(5)
        .reducer("wordcount-reducer")
        .workdir(&root)
        .pid(92051);
    let remote = run(&opts, &wordcount_apps(), &coordinator).unwrap();
    let reassigned: usize =
        remote.map.tasks.iter().map(|t| t.reassigned).sum();
    assert!(reassigned >= 1, "the doomed process dropped one task");
    let merged =
        fs::read_to_string(remote.redout_path.as_ref().unwrap()).unwrap();
    assert!(merged.contains("alpha"), "{merged}");

    // Coordinator shutdown tells the survivors to exit; reap everyone.
    drop(coordinator);
    for child in &mut children {
        let deadline =
            std::time::Instant::now() + Duration::from_secs(10);
        loop {
            match child.try_wait().unwrap() {
                Some(_) => break,
                None if std::time::Instant::now() > deadline => {
                    let _ = child.kill();
                    let _ = child.wait();
                    break;
                }
                None => std::thread::sleep(Duration::from_millis(50)),
            }
        }
    }
}

// ---------------------------------------------------------------------------
// PR 10: wire framing, batch shipping, and work stealing
// ---------------------------------------------------------------------------

use llmapreduce::scheduler::remote::WireMode;

/// Tentpole acceptance (PR 10): a mixed-version fleet — one worker
/// behaving like a pre-PR-10 build (no wire capability advertised, so
/// it must receive one line-JSON frame per task and never a batch or
/// revoke frame) next to a binary-framing worker — completes a job
/// byte-identically to a local run, with both workers contributing.
#[test]
fn mixed_version_fleet_wordcount_byte_identical() {
    let root = tmp("mixed");
    let input = root.join("input");
    write_corpus(&input, 12);

    let eng = LocalEngine::new(2);
    let local = run(
        &wordcount_opts(&input, &root.join("out-local"), 92061)
            .workdir(&root),
        &wordcount_apps(),
        &eng,
    )
    .unwrap();

    let coordinator = bind_coordinator(3000);
    let addr = coordinator.local_addr().to_string();
    let legacy = {
        let config = WorkerConfig::new(addr.clone())
            .name("old-timer")
            .slots(1)
            .legacy();
        std::thread::spawn(move || run_worker(config))
    };
    let modern = {
        let config = WorkerConfig::new(addr)
            .name("modern")
            .slots(1)
            .wire(WireMode::Binary);
        std::thread::spawn(move || run_worker(config))
    };
    coordinator
        .wait_for_workers(2, Duration::from_secs(10))
        .unwrap();

    let remote = run(
        &wordcount_opts(&input, &root.join("out-remote"), 92062)
            .workdir(&root),
        &wordcount_apps(),
        &coordinator,
    )
    .unwrap();

    assert_eq!(
        fs::read(local.redout_path.as_ref().unwrap()).unwrap(),
        fs::read(remote.redout_path.as_ref().unwrap()).unwrap(),
        "mixed-version fleet must produce byte-identical output"
    );
    let names: std::collections::HashSet<_> = remote
        .map
        .tasks
        .iter()
        .map(|t| t.worker.clone().unwrap())
        .collect();
    assert!(
        names.contains("old-timer") && names.contains("modern"),
        "both protocol generations completed work: {names:?}"
    );
    drop(coordinator);
    legacy.join().unwrap().unwrap();
    modern.join().unwrap().unwrap();
}

/// Batched binary framing end to end: a fleet that negotiated binary
/// frames and batch shipping produces output byte-identical to local,
/// and the steal/overcommit machinery never books a reassignment (a
/// stolen task is a move, not a failure).
#[test]
fn binary_batched_fleet_wordcount_byte_identical() {
    let root = tmp("binwire");
    let input = root.join("input");
    write_corpus(&input, 10);

    let eng = LocalEngine::new(2);
    let local = run(
        &wordcount_opts(&input, &root.join("out-local"), 92071)
            .np(8)
            .workdir(&root),
        &wordcount_apps(),
        &eng,
    )
    .unwrap();

    let coordinator = bind_coordinator(3000);
    let addr = coordinator.local_addr().to_string();
    let workers: Vec<_> = (0..2)
        .map(|i| {
            let config = WorkerConfig::new(addr.clone())
                .name(format!("bw{i}"))
                .slots(1)
                .wire(WireMode::Binary);
            std::thread::spawn(move || run_worker(config))
        })
        .collect();
    coordinator
        .wait_for_workers(2, Duration::from_secs(10))
        .unwrap();

    let remote = run(
        &wordcount_opts(&input, &root.join("out-remote"), 92072)
            .np(8)
            .workdir(&root),
        &wordcount_apps(),
        &coordinator,
    )
    .unwrap();

    assert_eq!(
        fs::read(local.redout_path.as_ref().unwrap()).unwrap(),
        fs::read(remote.redout_path.as_ref().unwrap()).unwrap(),
        "binary-framed fleet must produce byte-identical output"
    );
    for t in &remote.map.tasks {
        assert_eq!(t.reassigned, 0, "steals are moves, not failures");
    }
    drop(coordinator);
    for w in workers {
        w.join().unwrap().unwrap();
    }
}

/// Work stealing: submit a backlog to a lone worker, then attach a
/// second one.  The latecomer's registration finds the central queue
/// empty and pulls queued-but-unstarted tasks out of the first
/// worker's backlog (each revoked from the victim), so both workers
/// contribute — and no task books a reassignment, because a steal is
/// a move, not a death.
#[test]
fn idle_worker_steals_from_a_backlogged_peer() {
    let coordinator = bind_coordinator(3000);
    let addr = coordinator.local_addr().to_string();
    let first = {
        let config = WorkerConfig::new(addr.clone())
            .name("busy")
            .slots(1)
            .wire(WireMode::Binary);
        std::thread::spawn(move || run_worker(config))
    };
    coordinator
        .wait_for_workers(1, Duration::from_secs(10))
        .unwrap();

    // Eight ~100ms tasks: batch shipping overcommits all of them onto
    // the lone worker, which works through the backlog one at a time.
    let tasks: Vec<TaskSpec> = (0..8)
        .map(|i| TaskSpec {
            task_id: i + 1,
            work: TaskWork::Synthetic {
                startup: Duration::from_millis(10),
                per_item: Duration::from_millis(45),
                items: 2,
                launches: 1,
            },
        })
        .collect();
    let id = coordinator.submit(JobSpec::new("backlog", tasks)).unwrap();

    // Now attach the thief; its registration triggers a placement
    // round that finds the ready queue dry and steals from the busy
    // worker's backlog (still ~700ms deep at this point).
    let thief = {
        let config = WorkerConfig::new(addr)
            .name("thief")
            .slots(1)
            .wire(WireMode::Binary);
        std::thread::spawn(move || run_worker(config))
    };
    coordinator
        .wait_for_workers(2, Duration::from_secs(10))
        .unwrap();

    let report = coordinator.wait(id).unwrap();
    assert_eq!(report.tasks.len(), 8);
    let names: std::collections::HashSet<_> = report
        .tasks
        .iter()
        .map(|t| t.worker.clone().unwrap())
        .collect();
    assert!(
        names.contains("thief"),
        "latecomer must have stolen work: {names:?}"
    );
    for t in &report.tasks {
        assert_eq!(t.reassigned, 0, "steals must not book reassignments");
    }
    drop(coordinator);
    first.join().unwrap().unwrap();
    thief.join().unwrap().unwrap();
}
