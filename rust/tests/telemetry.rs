//! Telemetry acceptance suite (DESIGN.md §9).
//!
//! The bar: the live surfaces must agree with the ground truth the
//! engine reports.  `status.json` totals equal the final `JobReport`
//! on the local *and* remote engines; the remote coordinator's
//! `--metrics-listen` endpoint exposes task counters with per-worker
//! labels that sum to the same totals; and after a real SIGKILL,
//! `llmapreduce status` folds the journal to exactly the done/pending
//! split a subsequent `resume` acts on.

use std::fs;
use std::path::{Path, PathBuf};
use std::process::{Command, Stdio};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use llmapreduce::error::Result;
use llmapreduce::mapreduce::{run, Apps};
use llmapreduce::options::Options;
use llmapreduce::prelude::{
    run_worker, CoordinatorConfig, LocalEngine, RemoteCoordinator,
    WorkerConfig,
};
use llmapreduce::scheduler::journal::JOURNAL_FILE;
use llmapreduce::telemetry::{fetch, fold_workdir, STATUS_FILE};
use llmapreduce::util::json::Json;

fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("llmr-telemetry-{tag}-{}", std::process::id()));
    let _ = fs::remove_dir_all(&d);
    fs::create_dir_all(&d).unwrap();
    d
}

/// Deterministic corpus: overlapping word multisets across files.
fn write_corpus(input: &Path, nfiles: usize) {
    fs::create_dir_all(input).unwrap();
    let vocab = ["alpha", "beta", "gamma", "delta", "epsilon"];
    for i in 0..nfiles {
        let mut text = String::new();
        for (w, word) in vocab.iter().enumerate() {
            for _ in 0..(i + w) % 4 + 1 {
                text.push_str(word);
                text.push(' ');
            }
        }
        fs::write(input.join(format!("doc{i:02}.txt")), text).unwrap();
    }
}

fn wc_opts(input: &Path, output: PathBuf, pid: u32) -> Options {
    Options::new(input, output, "wordcount")
        .np(4)
        .reducer("wordcount-reducer")
        .pid(pid)
}

fn wc_apps() -> Apps {
    Apps {
        mapper: llmapreduce::apps::registry::resolve_mapper("wordcount")
            .unwrap(),
        reducer: Some(
            llmapreduce::apps::registry::resolve_reducer(
                "wordcount-reducer",
            )
            .unwrap(),
        ),
    }
}

fn num(j: Option<&Json>) -> usize {
    j.and_then(Json::as_usize).unwrap_or(usize::MAX)
}

// ---------------------------------------------------------------------------
// status.json totals == final JobReport (local engine)
// ---------------------------------------------------------------------------

#[test]
fn local_status_json_totals_match_the_job_report() {
    let root = tmp("local");
    let input = root.join("input");
    write_corpus(&input, 10);

    let eng = LocalEngine::new(2);
    let report = run(
        &wc_opts(&input, root.join("out"), 95001)
            .keep(true)
            .workdir(&root),
        &wc_apps(),
        &eng,
    )
    .unwrap();
    let map_tasks = report.map.tasks.len();
    assert_eq!(map_tasks, 4);

    // The invocation drop flushed a final snapshot before `run`
    // returned, so status.json is the completed picture.
    let wd = root.join(".MAPRED.95001");
    let status =
        Json::parse(&fs::read_to_string(wd.join(STATUS_FILE)).unwrap())
            .unwrap();
    assert_eq!(num(status.get("v")), 1);

    // Totals aggregate the map job and the reduce job (one task).
    let totals = status.get("totals").expect("totals");
    let all_tasks = map_tasks + 1;
    assert_eq!(num(totals.get("submitted")), all_tasks);
    assert_eq!(num(totals.get("done")), all_tasks);
    assert_eq!(num(totals.get("running")), 0);
    assert_eq!(num(totals.get("errors")), report.map.dead_lettered());
    assert_eq!(num(totals.get("failed_jobs")), 0);
    let retries: usize = report.map.tasks.iter().map(|t| t.retries).sum();
    assert_eq!(num(totals.get("retries")), retries);

    // Per-job rows carry the same counts and terminal states.
    let jobs = status.get("jobs").and_then(Json::as_obj).unwrap();
    assert_eq!(jobs.len(), 2, "map + reduce jobs");
    for j in jobs.values() {
        assert_eq!(
            j.get("state").and_then(Json::as_str),
            Some("done"),
            "every job completed: {j:?}"
        );
        assert_eq!(num(j.get("done")), num(j.get("ntasks")));
        assert_eq!(num(j.get("running")), 0);
    }
    let map_job = jobs
        .values()
        .find(|j| j.get("name").and_then(Json::as_str) == Some("wordcount"))
        .expect("map job present");
    assert_eq!(num(map_job.get("ntasks")), map_tasks);

    // Each completion recorded one observation per latency phase.
    let latency = status.get("latency").expect("latency");
    for phase in ["dispatch", "startup", "compute"] {
        let h = latency.get(phase).expect(phase);
        assert_eq!(num(h.get("count")), all_tasks, "{phase} count");
    }

    // The offline fold prefers the journal and reports the same
    // done/pending split; both renderers accept either shape.
    let fold = fold_workdir(&wd).unwrap();
    assert_eq!(fold.get("source").and_then(Json::as_str), Some("journal"));
    let map = fold.get("map").expect("map summary");
    assert_eq!(num(map.get("done")), map_tasks);
    assert_eq!(num(map.get("pending")), 0);
    let rendered = llmapreduce::telemetry::render_status(&fold);
    assert!(rendered.contains("wordcount"), "got: {rendered}");
    assert!(
        !llmapreduce::telemetry::render_top(&status).is_empty(),
        "live snapshot renders as a top frame"
    );
}

#[test]
fn telemetry_off_writes_no_status_file() {
    let root = tmp("off");
    let input = root.join("input");
    write_corpus(&input, 6);
    let eng = LocalEngine::new(2);
    run(
        &wc_opts(&input, root.join("out"), 95002)
            .telemetry(false)
            .keep(true)
            .workdir(&root),
        &wc_apps(),
        &eng,
    )
    .unwrap();
    let wd = root.join(".MAPRED.95002");
    assert!(wd.join(JOURNAL_FILE).is_file(), "journal unaffected");
    assert!(
        !wd.join(STATUS_FILE).exists(),
        "--telemetry=false must not write status.json"
    );
}

// ---------------------------------------------------------------------------
// Remote engine: /metrics + /status agree with the JobReport
// ---------------------------------------------------------------------------

fn spawn_workers(
    coordinator: &RemoteCoordinator,
    n: usize,
) -> Vec<JoinHandle<Result<()>>> {
    let addr = coordinator.local_addr().to_string();
    (0..n)
        .map(|i| {
            let config = WorkerConfig::new(addr.clone())
                .name(format!("w{i}"))
                .slots(1);
            std::thread::spawn(move || run_worker(config))
        })
        .collect()
}

/// Sum every series of a counter family in a Prometheus exposition,
/// returning the per-line label blocks seen along the way.
fn prometheus_counter(text: &str, family: &str) -> (usize, Vec<String>) {
    let mut total = 0usize;
    let mut labels = Vec::new();
    for line in text.lines() {
        let Some(rest) = line.strip_prefix(family) else {
            continue;
        };
        let Some((block, value)) = rest.rsplit_once(' ') else {
            continue;
        };
        // Skip longer family names sharing the prefix (e.g. _bucket).
        if !block.is_empty() && !block.starts_with('{') {
            continue;
        }
        total += value.parse::<usize>().unwrap_or(0);
        labels.push(block.to_string());
    }
    (total, labels)
}

#[test]
fn remote_metrics_endpoint_matches_the_job_report() {
    let root = tmp("remote");
    let input = root.join("input");
    write_corpus(&input, 10);

    let coordinator = RemoteCoordinator::bind(
        "127.0.0.1:0",
        CoordinatorConfig {
            metrics_listen: Some("127.0.0.1:0".to_string()),
            ..Default::default()
        },
    )
    .unwrap();
    let metrics_addr = coordinator
        .metrics_addr()
        .expect("metrics listener bound")
        .to_string();
    let workers = spawn_workers(&coordinator, 2);
    coordinator
        .wait_for_workers(2, Duration::from_secs(10))
        .unwrap();

    let report = run(
        &wc_opts(&input, root.join("out"), 95003)
            .keep(true)
            .workdir(&root),
        &wc_apps(),
        &coordinator,
    )
    .unwrap();
    let map_tasks = report.map.tasks.len();
    let all_tasks = map_tasks + 1;

    // Prometheus text: completed-task counters carry per-worker labels
    // and sum to the report's task count.
    let text = fetch(&metrics_addr, "/metrics").unwrap();
    assert!(text.contains("# TYPE llmr_tasks_done_total counter"));
    let (done, label_blocks) =
        prometheus_counter(&text, "llmr_tasks_done_total");
    assert_eq!(done, all_tasks, "exposition:\n{text}");
    let attributed: Vec<&String> = label_blocks
        .iter()
        .filter(|b| b.contains("worker=\"w0\"") || b.contains("worker=\"w1\""))
        .collect();
    assert!(
        !attributed.is_empty(),
        "done counters must be worker-labelled: {label_blocks:?}"
    );
    for t in &report.map.tasks {
        let w = t.worker.as_deref().expect("remote tasks attributed");
        assert!(
            label_blocks.iter().any(|b| b.contains(&format!(
                "worker=\"{w}\""
            ))),
            "worker {w} missing from exposition"
        );
    }
    let (submitted, _) =
        prometheus_counter(&text, "llmr_tasks_submitted_total");
    assert_eq!(submitted, all_tasks);
    assert!(
        text.contains("# TYPE llmr_task_compute_seconds histogram"),
        "latency histograms exposed"
    );
    assert!(text.contains("llmr_worker_slots{worker=\"w0\"}"));

    // JSON snapshot: same totals, per-worker attribution sums to the
    // task count, every registered worker present.
    let status =
        Json::parse(&fetch(&metrics_addr, "/status").unwrap()).unwrap();
    let totals = status.get("totals").expect("totals");
    assert_eq!(num(totals.get("done")), all_tasks);
    assert_eq!(num(totals.get("running")), 0);
    let snap_workers = status.get("workers").and_then(Json::as_obj).unwrap();
    assert_eq!(snap_workers.len(), 2, "both workers in the snapshot");
    let attributed: usize =
        snap_workers.values().map(|w| num(w.get("done"))).sum();
    assert_eq!(attributed, all_tasks, "every task attributed to a worker");

    // status.json in the workdir folds the *same* event stream.
    let wd = root.join(".MAPRED.95003");
    let file =
        Json::parse(&fs::read_to_string(wd.join(STATUS_FILE)).unwrap())
            .unwrap();
    assert_eq!(
        num(file.get("totals").and_then(|t| t.get("done"))),
        all_tasks
    );

    drop(coordinator);
    for w in workers {
        w.join().unwrap().unwrap();
    }
}

// ---------------------------------------------------------------------------
// SIGKILL + offline `status`: the fold a later `resume` acts on
// ---------------------------------------------------------------------------

const BIN: &str = env!("CARGO_BIN_EXE_llmapreduce");

fn wait_for_workdir(base: &Path, limit: Duration) -> PathBuf {
    let start = Instant::now();
    loop {
        if let Ok(entries) = fs::read_dir(base) {
            for e in entries.flatten() {
                let name = e.file_name().to_string_lossy().to_string();
                if name.starts_with(".MAPRED.") {
                    return e.path();
                }
            }
        }
        assert!(
            start.elapsed() < limit,
            "no .MAPRED.* workdir appeared under {}",
            base.display()
        );
        std::thread::sleep(Duration::from_millis(20));
    }
}

fn wait_for_first_done(wd: &Path, limit: Duration) {
    let start = Instant::now();
    let path = wd.join(JOURNAL_FILE);
    loop {
        if let Ok(text) = fs::read_to_string(&path) {
            if text.contains("\"rec\":\"done\"") {
                return;
            }
        }
        assert!(
            start.elapsed() < limit,
            "no task completed within {limit:?} ({})",
            path.display()
        );
        std::thread::sleep(Duration::from_millis(20));
    }
}

#[test]
fn sigkilled_run_status_fold_matches_what_resume_replays() {
    let root = tmp("sigkill");
    let input = root.join("input");
    write_corpus(&input, 8);
    let slow = root.join("slow-map.sh");
    fs::write(
        &slow,
        "#!/bin/sh\nsleep 0.3\ntr 'a-z' 'A-Z' < \"$1\" > \"$2\"\n",
    )
    .unwrap();
    let mapper = format!("sh {}", slow.display());

    let crash_base = root.join("crash");
    fs::create_dir_all(&crash_base).unwrap();
    let mut child = Command::new(BIN)
        .current_dir(&root)
        .arg("run")
        .args([
            format!("--input={}", input.display()),
            format!("--output={}", root.join("out").display()),
            format!("--mapper={mapper}"),
            "--np=8".to_string(),
            "--keep=true".to_string(),
            format!("--workdir={}", crash_base.display()),
            "--slots=2".to_string(),
        ])
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .unwrap();
    let wd = wait_for_workdir(&crash_base, Duration::from_secs(60));
    wait_for_first_done(&wd, Duration::from_secs(60));
    child.kill().unwrap(); // SIGKILL: no final status flush, no cleanup
    let _ = child.wait();

    // `status --json`: the journal fold is authoritative even though
    // the SIGKILL may have left status.json a batch behind (or absent).
    let out = Command::new(BIN)
        .args([
            "status".to_string(),
            wd.display().to_string(),
            "--json".to_string(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "status failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let fold =
        Json::parse(&String::from_utf8_lossy(&out.stdout)).unwrap();
    assert_eq!(fold.get("source").and_then(Json::as_str), Some("journal"));
    let map = fold.get("map").expect("map summary");
    let done = num(map.get("done"));
    let pending = num(map.get("pending"));
    assert_eq!(num(map.get("ntasks")), 8);
    assert_eq!(done + pending, 8);
    assert!(done >= 1, "killed after the first completion");
    assert!(pending >= 1, "killed mid-job");

    // The human rendering reports the same split.
    let out = Command::new(BIN)
        .args(["status".to_string(), wd.display().to_string()])
        .output()
        .unwrap();
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(
        text.contains(&format!("{done}/8 done")) &&
            text.contains(&format!("{pending} pending re-run")),
        "got: {text}"
    );

    // One `top` frame folds the same workdir offline.
    let out = Command::new(BIN)
        .args([
            "top".to_string(),
            wd.display().to_string(),
            "--frames=1".to_string(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success());
    let frame = String::from_utf8_lossy(&out.stdout);
    assert!(frame.contains("queue "), "got: {frame}");

    // `resume` must act on exactly the counts `status` reported.
    let out = Command::new(BIN)
        .current_dir(&root)
        .args([
            "resume".to_string(),
            wd.display().to_string(),
            "--slots=4".to_string(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "resume failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(
        text.contains(&format!(
            "{done} task(s) already complete (skipped), {pending} re-run"
        )),
        "status said {done} done/{pending} pending, resume said: {text}"
    );
}

// ---------------------------------------------------------------------------
// `top` failure modes: no journal / unreachable endpoint
// ---------------------------------------------------------------------------

#[test]
fn top_on_a_workdir_without_a_journal_fails_with_one_line() {
    let root = tmp("top-empty");
    // A directory with neither journal.jsonl nor status.json.
    let out = Command::new(BIN)
        .args([
            "top".to_string(),
            root.display().to_string(),
            "--frames=1".to_string(),
        ])
        .output()
        .unwrap();
    assert!(
        !out.status.success(),
        "top must exit nonzero on an empty workdir"
    );
    let stderr = String::from_utf8_lossy(&out.stderr);
    let lines: Vec<&str> =
        stderr.lines().filter(|l| !l.trim().is_empty()).collect();
    assert_eq!(lines.len(), 1, "one-line error, got: {stderr}");
    assert!(
        lines[0].starts_with("error:")
            && lines[0].contains("nothing to report"),
        "got: {stderr}"
    );
}

#[test]
fn top_on_an_unreachable_endpoint_fails_fast_with_one_line() {
    // Port 1 is reserved and nothing listens on it; the connect must
    // be refused (or time out at the 2s connect deadline), never hang.
    let start = Instant::now();
    let out = Command::new(BIN)
        .args([
            "top".to_string(),
            "127.0.0.1:1".to_string(),
            "--frames=1".to_string(),
        ])
        .output()
        .unwrap();
    assert!(
        !out.status.success(),
        "top must exit nonzero on an unreachable endpoint"
    );
    assert!(
        start.elapsed() < Duration::from_secs(30),
        "top hung instead of failing fast ({:?})",
        start.elapsed()
    );
    let stderr = String::from_utf8_lossy(&out.stderr);
    let lines: Vec<&str> =
        stderr.lines().filter(|l| !l.trim().is_empty()).collect();
    assert_eq!(lines.len(), 1, "one-line error, got: {stderr}");
    assert!(
        lines[0].starts_with("error:") && lines[0].contains("127.0.0.1:1"),
        "the error names the endpoint, got: {stderr}"
    );
}

// ---------------------------------------------------------------------------
// Golden schema: the status.json / /status field names are a contract
// ---------------------------------------------------------------------------

/// Pin the snapshot schema documented in docs/telemetry.md.  Renaming
/// or dropping a field is a breaking change for every scraper pointed
/// at status.json or a `--metrics-listen` /status endpoint — this test
/// is the tripwire.
#[test]
fn status_snapshot_schema_field_names_are_pinned() {
    let root = tmp("golden");
    let input = root.join("input");
    write_corpus(&input, 6);
    let eng = LocalEngine::new(2);
    run(
        &wc_opts(&input, root.join("out"), 95004)
            .keep(true)
            .workdir(&root),
        &wc_apps(),
        &eng,
    )
    .unwrap();
    let wd = root.join(".MAPRED.95004");
    let status =
        Json::parse(&fs::read_to_string(wd.join(STATUS_FILE)).unwrap())
            .unwrap();

    let keys = |j: &Json| -> Vec<String> {
        j.as_obj()
            .expect("object")
            .keys()
            .cloned()
            .collect()
    };
    // Top level (sorted — the writer emits objects in key order).
    // `resumed` only appears on resumed invocations.
    assert_eq!(
        keys(&status),
        [
            "at_ms",
            "jobs",
            "latency",
            "metrics",
            "queue_depth",
            "seq",
            "totals",
            "v",
            "workers"
        ],
        "top-level status.json schema changed"
    );
    assert_eq!(num(status.get("v")), 1, "schema version");
    assert_eq!(
        keys(status.get("totals").unwrap()),
        ["done", "errors", "failed_jobs", "retries", "running", "submitted"],
        "totals schema changed"
    );
    let jobs = status.get("jobs").and_then(Json::as_obj).unwrap();
    assert!(!jobs.is_empty());
    for j in jobs.values() {
        assert_eq!(
            keys(j),
            [
                "done",
                "errors",
                "failed",
                "name",
                "ntasks",
                "reassigned",
                "retries",
                "running",
                "state",
                "task_errors"
            ],
            "per-job schema changed"
        );
    }
    assert_eq!(
        keys(status.get("latency").unwrap()),
        ["compute", "dispatch", "startup"],
        "latency schema changed"
    );
    // Worker rows only exist on the remote engine; the key itself is
    // part of the contract either way.
    assert!(status.get("workers").and_then(Json::as_obj).is_some());
    assert!(status.get("metrics").is_some());
}
