//! Crash-recovery acceptance suite (DESIGN.md §8).
//!
//! The bar is *byte identity*: a job whose coordinator dies mid-run —
//! whether simulated by truncating the journal to a crash-point prefix
//! or by SIGKILLing a real `llmapreduce` process — must, after
//! `resume`, produce merged output bit-for-bit identical to an
//! uninterrupted run.  Coverage: plain, `--overlap`, SPMD batches
//! (which re-run whole), deterministic retry replay under a shared
//! [`FailurePolicy`] seed, the real binary on the local *and* remote
//! engines, the dead-letter queue drain, and the failure-rate circuit
//! breaker.

use std::fs;
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use llmapreduce::apps::registry::{resolve_mapper, resolve_reducer};
use llmapreduce::mapreduce::{
    dlq_reprocess, resume, run, Apps, MapReduceReport,
};
use llmapreduce::options::Options;
use llmapreduce::prelude::{FailurePolicy, LocalEngine, OnError};
use llmapreduce::scheduler::journal::{Replay, DLQ_FILE, JOURNAL_FILE};
use llmapreduce::util::json::Json;

fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("llmr-resume-it-{tag}-{}", std::process::id()));
    let _ = fs::remove_dir_all(&d);
    fs::create_dir_all(&d).unwrap();
    d
}

/// Deterministic corpus: overlapping word multisets across files.
fn write_corpus(input: &Path, nfiles: usize) {
    fs::create_dir_all(input).unwrap();
    let vocab = ["alpha", "beta", "gamma", "delta", "epsilon"];
    for i in 0..nfiles {
        let mut text = String::new();
        for (w, word) in vocab.iter().enumerate() {
            for _ in 0..(i + w) % 4 + 1 {
                text.push_str(word);
                text.push(' ');
            }
        }
        fs::write(input.join(format!("doc{i:02}.txt")), text).unwrap();
    }
}

fn wc_opts(input: &Path, output: PathBuf, pid: u32) -> Options {
    Options::new(input, output, "wordcount")
        .np(4)
        .reducer("wordcount-reducer")
        .pid(pid)
}

fn wc_apps() -> Apps {
    Apps {
        mapper: resolve_mapper("wordcount").unwrap(),
        reducer: Some(resolve_reducer("wordcount-reducer").unwrap()),
    }
}

fn redout(report: &MapReduceReport) -> Vec<u8> {
    fs::read(report.redout_path.as_ref().expect("reduced")).unwrap()
}

/// Simulate a coordinator crash: a dead process leaves an arbitrary
/// prefix of its append-only journal, so truncating the file right
/// after the `k`-th map-task `done` record *is* the crash state (plus
/// whatever stale output files the run left behind — resume must
/// overwrite those, exactly as it would after a real crash).
fn truncate_journal_after_dones(wd: &Path, mapper: &str, k: usize) {
    let path = wd.join(JOURNAL_FILE);
    let text = fs::read_to_string(&path).unwrap();
    let mut map_job: Option<usize> = None;
    let mut kept: Vec<&str> = Vec::new();
    let mut dones = 0usize;
    for line in text.lines() {
        let doc = Json::parse(line).unwrap();
        let rec = doc.get("rec").and_then(Json::as_str).unwrap();
        let job = doc.get("job").and_then(Json::as_usize);
        if rec == "job"
            && map_job.is_none()
            && doc.get("name").and_then(Json::as_str) == Some(mapper)
        {
            map_job = job;
        }
        kept.push(line);
        if rec == "done" && map_job.is_some() && job == map_job {
            dones += 1;
            if dones == k {
                break;
            }
        }
    }
    assert_eq!(dones, k, "journal holds at least {k} map completions");
    fs::write(&path, format!("{}\n", kept.join("\n"))).unwrap();
}

// ---------------------------------------------------------------------------
// Journal-truncation crashes: byte identity on the local engine
// ---------------------------------------------------------------------------

#[test]
fn truncated_journal_resume_is_byte_identical() {
    let root = tmp("local");
    let input = root.join("input");
    write_corpus(&input, 10);

    let eng = LocalEngine::new(2);
    let baseline = run(
        &wc_opts(&input, root.join("out-base"), 94001).workdir(&root),
        &wc_apps(),
        &eng,
    )
    .unwrap();
    let base_bytes = redout(&baseline);
    assert!(!base_bytes.is_empty());

    let crashed = run(
        &wc_opts(&input, root.join("out-crash"), 94002)
            .keep(true)
            .workdir(&root),
        &wc_apps(),
        &eng,
    )
    .unwrap();
    assert_eq!(crashed.map.tasks.len(), 4);
    let wd = root.join(".MAPRED.94002");
    assert!(wd.is_dir(), "--keep preserves the workdir + journal");
    truncate_journal_after_dones(&wd, "wordcount", 2);

    let done = Replay::load(&wd.join(JOURNAL_FILE))
        .unwrap()
        .done_task_ids("wordcount");
    assert_eq!(done.len(), 2);

    let resumed = resume(&wd, &eng).unwrap();
    assert_eq!(resumed.map.replayed, 2, "two tasks skipped as done");
    assert_eq!(resumed.map.tasks.len(), 2, "two tasks re-run");
    for t in &resumed.map.tasks {
        assert!(
            !done.contains(&t.task_id),
            "task {} was journaled done and must not re-run",
            t.task_id
        );
    }
    assert!(resumed.reduce.is_some(), "the reduce always re-runs");
    assert_eq!(
        redout(&resumed),
        base_bytes,
        "resumed output must match an uninterrupted run byte-for-byte"
    );

    // Resume-of-resume: the appended generation marked everything done.
    let again = resume(&wd, &eng).unwrap();
    assert_eq!(again.map.replayed, 4);
    assert_eq!(again.map.tasks.len(), 0);
    assert_eq!(redout(&again), base_bytes);
    assert!(wd.is_dir(), "journal recorded --keep, so resume keeps too");
}

#[test]
fn overlap_crash_resumes_to_identical_bytes() {
    let root = tmp("overlap");
    let input = root.join("input");
    write_corpus(&input, 8);

    let eng = LocalEngine::new(2);
    let baseline = run(
        &wc_opts(&input, root.join("out-base"), 94011).workdir(&root),
        &wc_apps(),
        &eng,
    )
    .unwrap();

    let crashed = run(
        &wc_opts(&input, root.join("out-crash"), 94012)
            .overlap(true)
            .keep(true)
            .workdir(&root),
        &wc_apps(),
        &eng,
    )
    .unwrap();
    assert!(crashed.overlapped);
    let wd = root.join(".MAPRED.94012");
    truncate_journal_after_dones(&wd, "wordcount", 1);

    // Overlap is not resumed: the recovered run barriers a classic
    // reduce over the full output dir (crashed partials are untrusted
    // scratch) — and still merges to the same bytes.
    let resumed = resume(&wd, &eng).unwrap();
    assert!(!resumed.overlapped);
    assert!(resumed.partials.is_none());
    assert_eq!(resumed.map.replayed, 1);
    assert_eq!(resumed.map.tasks.len(), 3);
    assert_eq!(redout(&resumed), redout(&baseline));
}

#[test]
fn spmd_batches_resume_whole() {
    let root = tmp("spmd");
    let input = root.join("input");
    write_corpus(&input, 8);

    let eng = LocalEngine::new(2);
    let baseline = run(
        &wc_opts(&input, root.join("out-base"), 94021).workdir(&root),
        &wc_apps(),
        &eng,
    )
    .unwrap();

    let crashed = run(
        &wc_opts(&input, root.join("out-crash"), 94022)
            .items_per_task(3)
            .keep(true)
            .workdir(&root),
        &wc_apps(),
        &eng,
    )
    .unwrap();
    assert_eq!(crashed.map.tasks.len(), 3, "8 files at N=3 → 3 batches");
    let wd = root.join(".MAPRED.94022");
    truncate_journal_after_dones(&wd, "wordcount", 1);

    let resumed = resume(&wd, &eng).unwrap();
    assert_eq!(resumed.map.replayed, 1, "the finished batch is skipped");
    assert_eq!(resumed.map.tasks.len(), 2);
    for t in &resumed.map.tasks {
        // The batch is the unit of recovery: it re-runs whole, in one
        // persistent app launch, never item-by-item.
        assert_eq!(t.launches, 1, "one persistent launch per batch");
        assert!(t.items >= 2 && t.items <= 3, "whole batch re-ran");
    }
    assert_eq!(redout(&resumed), redout(&baseline));
}

// ---------------------------------------------------------------------------
// Deterministic retry replay (journaled schedules recompute on resume)
// ---------------------------------------------------------------------------

#[test]
fn resumed_retries_replay_the_failure_policy() {
    let root = tmp("retries");
    let input = root.join("input");
    write_corpus(&input, 10);
    let policy = FailurePolicy {
        failure_rate: 0.6,
        max_retries: 4,
        seed: 0xD1CE,
    };

    // Uninterrupted run under the policy: the retry pattern is the
    // closed-form function of (seed, task_id, attempt).
    let eng = LocalEngine::with_policy(2, policy);
    let baseline = run(
        &wc_opts(&input, root.join("out-base"), 94031)
            .keep(true)
            .workdir(&root),
        &wc_apps(),
        &eng,
    )
    .unwrap();
    let base_bytes = redout(&baseline);
    let mut base_retries: Vec<(usize, usize)> = baseline
        .map
        .tasks
        .iter()
        .map(|t| (t.task_id, t.retries))
        .collect();
    base_retries.sort();
    assert_eq!(
        base_retries,
        (1..=4)
            .map(|t| (t, policy.expected_retries(t)))
            .collect::<Vec<_>>(),
        "full run matches the policy's prediction"
    );

    // Crash after two completions, resume on a *fresh* engine with the
    // same policy: every re-run task replays its own schedule exactly —
    // the resumed job reports the same expected_retries per task id.
    let wd = root.join(".MAPRED.94031");
    truncate_journal_after_dones(&wd, "wordcount", 2);
    let fresh = LocalEngine::with_policy(2, policy);
    let resumed = resume(&wd, &fresh).unwrap();
    assert_eq!(resumed.map.tasks.len(), 2);
    for t in &resumed.map.tasks {
        assert_eq!(
            t.retries,
            policy.expected_retries(t.task_id),
            "task {} must replay its journaled retry schedule",
            t.task_id
        );
    }
    assert_eq!(redout(&resumed), base_bytes);
}

// ---------------------------------------------------------------------------
// Dead-letter queue: drain and reprocess
// ---------------------------------------------------------------------------

#[test]
fn dead_letters_drain_through_dlq_reprocess() {
    let root = tmp("dlq");
    let input = root.join("input");
    fs::create_dir_all(&input).unwrap();
    for i in 0..6 {
        let word = if i % 3 == 0 { "poison" } else { "fine" };
        fs::write(
            input.join(format!("f{i}.txt")),
            format!("{word} item{i}\n"),
        )
        .unwrap();
    }
    // Mapper fails on poison inputs until the marker file appears; the
    // reducer concatenates sorted for determinism.
    let marker = root.join("MARKER");
    let map_sh = root.join("map.sh");
    fs::write(
        &map_sh,
        "#!/bin/sh\n\
         if grep -q poison \"$2\" && [ ! -e \"$1\" ]; then exit 3; fi\n\
         tr 'a-z' 'A-Z' < \"$2\" > \"$3\"\n",
    )
    .unwrap();
    let red_sh = root.join("red.sh");
    fs::write(&red_sh, "#!/bin/sh\ncat \"$1\"/*.out | sort > \"$2\"\n")
        .unwrap();
    let mapper_spec =
        format!("sh {} {}", map_sh.display(), marker.display());
    let reducer_spec = format!("sh {}", red_sh.display());
    let apps = || Apps {
        mapper: resolve_mapper(&mapper_spec).unwrap(),
        reducer: Some(resolve_reducer(&reducer_spec).unwrap()),
    };
    let mapper_name = apps().mapper.name().to_string();
    let mk = |out: &str, pid: u32| {
        Options::new(&input, root.join(out), &mapper_spec)
            .reducer(&reducer_spec)
            .redout("merged.txt")
            .pid(pid)
            .workdir(&root)
    };

    // Healthy reference: marker present from the start.
    fs::write(&marker, "").unwrap();
    let eng = LocalEngine::new(2);
    let reference = run(&mk("out-ref", 94041), &apps(), &eng).unwrap();
    let ref_bytes = redout(&reference);

    // Degraded run: poison tasks dead-letter, the job still completes.
    fs::remove_file(&marker).unwrap();
    let degraded = run(
        &mk("out-dlq", 94042).on_error(OnError::Dlq),
        &apps(),
        &eng,
    )
    .unwrap();
    assert_eq!(degraded.map.dead_lettered(), 2);
    assert_ne!(redout(&degraded), ref_bytes, "poison contributions lost");
    let wd = root.join(".MAPRED.94042");
    assert!(
        wd.is_dir(),
        "dead-lettered runs keep their scratch: the journal and queue \
         are what reprocessing needs"
    );
    assert!(wd.join(DLQ_FILE).is_file());
    let replay = Replay::load(&wd.join(JOURNAL_FILE)).unwrap();
    assert_eq!(replay.dead_lettered_task_ids(&mapper_name).len(), 2);

    // Heal the environment and drain the queue.
    fs::write(&marker, "").unwrap();
    let reprocessed = dlq_reprocess(&wd, &eng).unwrap();
    assert_eq!(
        reprocessed.map.tasks.len(),
        2,
        "exactly the dead-lettered tasks resubmit"
    );
    assert_eq!(reprocessed.map.dead_lettered(), 0);
    assert_eq!(
        redout(&reprocessed),
        ref_bytes,
        "reprocessing restores the healthy run's bytes"
    );
    assert!(
        !wd.join(DLQ_FILE).exists(),
        "the queue is consumed at resubmission"
    );
    assert!(dlq_reprocess(&wd, &eng).is_err(), "nothing left to drain");
}

// ---------------------------------------------------------------------------
// Circuit breaker
// ---------------------------------------------------------------------------

#[test]
fn circuit_breaker_halts_the_job_and_keeps_the_journal() {
    let root = tmp("breaker");
    let input = root.join("input");
    write_corpus(&input, 8);
    let boom = root.join("boom.sh");
    fs::write(&boom, "#!/bin/sh\nexit 7\n").unwrap();
    let spec = format!("sh {}", boom.display());

    let opts = Options::new(&input, root.join("out"), &spec)
        .np(4)
        .pid(94051)
        .workdir(&root)
        .on_error(OnError::Dlq)
        .failure_threshold(0.3);
    let apps = Apps {
        mapper: resolve_mapper(&spec).unwrap(),
        reducer: None,
    };
    let eng = LocalEngine::new(2);
    let err = run(&opts, &apps, &eng).unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("circuit breaker"), "got: {msg}");

    // The failed run keeps its workdir, and the journal attributes the
    // halt to the breaker.
    let wd = root.join(".MAPRED.94051");
    assert!(wd.is_dir(), "failed runs keep the journal for resume");
    let replay = Replay::load(&wd.join(JOURNAL_FILE)).unwrap();
    let job = replay
        .jobs
        .values()
        .find(|j| j.breaker)
        .expect("breaker trip journaled");
    assert!(job.failed.is_some(), "the job-failed record follows");
    assert!(replay.consistent());
}

// ---------------------------------------------------------------------------
// Real SIGKILL through the binary: local and remote engines
// ---------------------------------------------------------------------------

const BIN: &str = env!("CARGO_BIN_EXE_llmapreduce");

fn wait_exit(child: &mut Child, what: &str, limit: Duration) {
    let start = Instant::now();
    loop {
        match child.try_wait().unwrap() {
            Some(st) => {
                assert!(st.success(), "{what} exited with {st}");
                return;
            }
            None if start.elapsed() > limit => {
                let _ = child.kill();
                panic!("{what} did not finish within {limit:?}");
            }
            None => std::thread::sleep(Duration::from_millis(25)),
        }
    }
}

/// Find the `.MAPRED.<pid>` directory a spawned run creates (the
/// subprocess picks its own pid).
fn wait_for_workdir(base: &Path, limit: Duration) -> PathBuf {
    let start = Instant::now();
    loop {
        if let Ok(entries) = fs::read_dir(base) {
            for e in entries.flatten() {
                let name = e.file_name().to_string_lossy().to_string();
                if name.starts_with(".MAPRED.") {
                    return e.path();
                }
            }
        }
        assert!(
            start.elapsed() < limit,
            "no .MAPRED.* workdir appeared under {}",
            base.display()
        );
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// Block until the journal records at least one task completion — the
/// kill that follows is guaranteed to land mid-job (each mapper task
/// sleeps long enough that several waves remain).
fn wait_for_first_done(wd: &Path, limit: Duration) {
    let start = Instant::now();
    let path = wd.join(JOURNAL_FILE);
    loop {
        if let Ok(text) = fs::read_to_string(&path) {
            if text.contains("\"rec\":\"done\"") {
                return;
            }
        }
        assert!(
            start.elapsed() < limit,
            "no task completed within {limit:?} ({})",
            path.display()
        );
        std::thread::sleep(Duration::from_millis(20));
    }
}

fn wait_for_listener(port: u16, limit: Duration) {
    let start = Instant::now();
    let addr = format!("127.0.0.1:{port}");
    loop {
        // A connect-and-drop probe: the coordinator tolerates
        // handshake-less connections (port-scanner discipline).
        if TcpStream::connect(&addr).is_ok() {
            return;
        }
        assert!(
            start.elapsed() < limit,
            "no listener on {addr} within {limit:?}"
        );
        std::thread::sleep(Duration::from_millis(25));
    }
}

/// Shared scaffolding for the binary tests: input corpus, a slow
/// mapper (guarantees the SIGKILL lands mid-job), a fast mapper for
/// the reference run, and a deterministic concatenating reducer.
struct BinFixture {
    root: PathBuf,
    input: PathBuf,
    slow_mapper: String,
    fast_mapper: String,
    reducer: String,
}

fn bin_fixture(tag: &str) -> BinFixture {
    let root = tmp(tag);
    let input = root.join("input");
    write_corpus(&input, 8);
    let slow = root.join("slow-map.sh");
    fs::write(
        &slow,
        "#!/bin/sh\nsleep 0.3\ntr 'a-z' 'A-Z' < \"$1\" > \"$2\"\n",
    )
    .unwrap();
    let fast = root.join("fast-map.sh");
    fs::write(&fast, "#!/bin/sh\ntr 'a-z' 'A-Z' < \"$1\" > \"$2\"\n")
        .unwrap();
    let red = root.join("red.sh");
    fs::write(&red, "#!/bin/sh\ncat \"$1\"/*.out | sort > \"$2\"\n")
        .unwrap();
    BinFixture {
        input,
        slow_mapper: format!("sh {}", slow.display()),
        fast_mapper: format!("sh {}", fast.display()),
        reducer: format!("sh {}", red.display()),
        root,
    }
}

impl BinFixture {
    /// Fig 2 argument block shared by every spawned run.
    fn run_args(&self, out: &str, mapper: &str, base: &Path) -> Vec<String> {
        vec![
            "run".into(),
            format!("--input={}", self.input.display()),
            format!("--output={}", self.root.join(out).display()),
            format!("--mapper={mapper}"),
            format!("--reducer={}", self.reducer),
            "--redout=merged.txt".into(),
            "--np=8".into(),
            "--keep=true".into(),
            format!("--workdir={}", base.display()),
        ]
    }

    /// Clean reference bytes via the same binary on the local engine.
    fn reference_bytes(&self) -> Vec<u8> {
        let base = self.root.join("clean");
        fs::create_dir_all(&base).unwrap();
        let mapper = self.fast_mapper.clone();
        let st = Command::new(BIN)
            .current_dir(&self.root)
            .args(self.run_args("out-clean", &mapper, &base))
            .arg("--slots=4")
            .stdout(Stdio::null())
            .stderr(Stdio::inherit())
            .status()
            .unwrap();
        assert!(st.success(), "reference run failed");
        fs::read(self.root.join("out-clean/merged.txt")).unwrap()
    }
}

#[test]
fn sigkilled_coordinator_resumes_via_the_binary() {
    let fx = bin_fixture("sigkill-local");
    let ref_bytes = fx.reference_bytes();

    // Launch the slow run and SIGKILL it after the first completion:
    // 8 tasks × 0.3s over 2 slots leave ≥3 waves outstanding, so the
    // kill cannot race a clean finish (and --keep=true de-flakes even
    // a pathological scheduler stall).
    let crash_base = fx.root.join("crash");
    fs::create_dir_all(&crash_base).unwrap();
    let mut child = Command::new(BIN)
        .current_dir(&fx.root)
        .args(fx.run_args("out-crash", &fx.slow_mapper, &crash_base))
        .arg("--slots=2")
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .unwrap();
    let wd = wait_for_workdir(&crash_base, Duration::from_secs(60));
    wait_for_first_done(&wd, Duration::from_secs(60));
    child.kill().unwrap(); // SIGKILL: no Drop, no cleanup
    let _ = child.wait();
    assert!(
        wd.join(JOURNAL_FILE).is_file(),
        "SIGKILL must leave the journal behind"
    );

    let out = Command::new(BIN)
        .current_dir(&fx.root)
        .args([
            "resume".to_string(),
            wd.display().to_string(),
            "--slots=4".to_string(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "resume failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("resumed"), "got: {text}");
    // The recovery summary (metrics::report::recovery_summary) must be
    // surfaced on resume stdout, not just computed: its header row names
    // the replayed/re-run split the operator acts on.
    for col in ["replayed", "re-run", "retries", "dead-lettered"] {
        assert!(
            text.contains(col),
            "resume stdout must print the recovery summary \
             (missing '{col}'): {text}"
        );
    }
    assert_eq!(
        fs::read(fx.root.join("out-crash/merged.txt")).unwrap(),
        ref_bytes,
        "post-crash merge must equal the uninterrupted run"
    );
}

#[test]
fn sigkilled_remote_coordinator_resumes_over_a_fresh_fleet() {
    let fx = bin_fixture("sigkill-remote");
    let ref_bytes = fx.reference_bytes();
    // Two ports per test process, clear of the ephemeral range.
    let port1 = 21000 + (std::process::id() % 39000) as u16;
    let port2 = port1 + 1;

    let crash_base = fx.root.join("crash");
    fs::create_dir_all(&crash_base).unwrap();
    let mut coord = Command::new(BIN)
        .current_dir(&fx.root)
        .args(fx.run_args("out-crash", &fx.slow_mapper, &crash_base))
        .args([
            "--engine=remote".to_string(),
            format!("--listen=127.0.0.1:{port1}"),
            "--min-workers=1".to_string(),
        ])
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .unwrap();
    wait_for_listener(port1, Duration::from_secs(60));
    let mut worker1 = Command::new(BIN)
        .args([
            "worker".to_string(),
            format!("--connect=127.0.0.1:{port1}"),
            "--slots=2".to_string(),
            "--name=w1".to_string(),
        ])
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .unwrap();
    let wd = wait_for_workdir(&crash_base, Duration::from_secs(60));
    wait_for_first_done(&wd, Duration::from_secs(120));
    coord.kill().unwrap();
    let _ = coord.wait();
    let _ = worker1.kill(); // the fleet dies with its coordinator
    let _ = worker1.wait();

    // Resume on a fresh port with a fresh worker: only the unfinished
    // tasks ship again.
    let mut res = Command::new(BIN)
        .current_dir(&fx.root)
        .args([
            "resume".to_string(),
            wd.display().to_string(),
            "--engine=remote".to_string(),
            format!("--listen=127.0.0.1:{port2}"),
            "--min-workers=1".to_string(),
        ])
        .stdout(Stdio::null())
        .stderr(Stdio::inherit())
        .spawn()
        .unwrap();
    wait_for_listener(port2, Duration::from_secs(60));
    let mut worker2 = Command::new(BIN)
        .args([
            "worker".to_string(),
            format!("--connect=127.0.0.1:{port2}"),
            "--slots=2".to_string(),
            "--name=w2".to_string(),
        ])
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .unwrap();
    wait_exit(&mut res, "remote resume", Duration::from_secs(120));
    let _ = worker2.kill();
    let _ = worker2.wait();

    assert_eq!(
        fs::read(fx.root.join("out-crash/merged.txt")).unwrap(),
        ref_bytes,
        "remote crash + resume must merge to the reference bytes"
    );
}
